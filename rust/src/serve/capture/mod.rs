//! Flight recorder: deterministic traffic capture and regression
//! replay for the serving stack.
//!
//! Three pieces, layered like the durable registry:
//!
//! * [`CaptureLog`] ([`codec`]) — a durable, append-only corpus of
//!   request records (kind, speaker, features, arrival offset on one
//!   capture-epoch clock, deadline, typed outcome, per-stage trace
//!   spans), length-prefixed + CRC-checksummed + seq-numbered behind a
//!   fingerprint-pinned `IVCL` header. Written over the existing
//!   [`RegistryStorage`] trait, so the same file backend and the same
//!   deterministic `FaultInjector` the registry WAL uses apply here.
//!   Replay is torn-tail-tolerant exactly like `registry/wal.rs`: a
//!   crash mid-append costs at most the final record, never the corpus.
//! * [`Recorder`] ([`recorder`]) — the tap. Hooked at `Engine`
//!   admission and `Dispatcher::dispatch_full`, it samples finished
//!   requests (`all` / `rate 1/N` / `slow_only` riding the obs trace
//!   threshold / `errors_only`) onto a **bounded** channel drained by a
//!   background writer thread. Capture can never block or slow a
//!   request thread: a full queue drops the record and counts it
//!   (`capture_dropped_total`) — never silently, never by waiting.
//! * [`Replayer`] ([`replay`]) — re-issues a captured corpus through a
//!   fresh engine at original inter-arrival timing or flat out,
//!   verifies scores to 1e-10 against the recorded outcomes when the
//!   bundle fingerprint matches, and diffs outcome classes + per-stage
//!   latency distributions against the capture.
//!
//! Together they close the observe half of the ROADMAP's "traffic
//! capture → replay → continuous retraining" loop: captured corpora are
//! deterministic regression load for candidate re-trained extractors.

mod codec;
mod recorder;
mod replay;

pub use codec::{CaptureError, CaptureRecord, CaptureReplay, RequestKind};
pub use recorder::{CaptureSummary, Recorder, RecorderOptions, SamplePolicy};
pub use replay::{
    replay_corpus, run_capture_overhead, CaptureOverhead, ReplayOptions, ReplayReport,
    StageDrift,
};

use std::path::Path;

use anyhow::{Context, Result};

use super::registry::{FileStorage, RegistryStorage};

/// Durable sink for one capture session: owns the storage backend,
/// assigns sequence numbers, and tracks what actually landed.
///
/// A write failure (ENOSPC, a scripted fault) permanently latches the
/// log dead: appending past a failed write would leave mid-log garbage
/// that replay must refuse wholesale, so the log refuses to append
/// instead — the recorder counts the refusals as drops.
pub struct CaptureLog {
    storage: Box<dyn RegistryStorage>,
    fingerprint: u64,
    next_seq: u64,
    records: u64,
    bytes: u64,
    dead: bool,
}

impl CaptureLog {
    /// Start a fresh capture over `storage` for a bundle with the given
    /// fingerprint. Truncates any previous log and writes the header.
    pub fn create(storage: Box<dyn RegistryStorage>, fingerprint: u64) -> Result<Self> {
        storage.truncate_wal(0).context("reset capture log")?;
        let header = codec::header(fingerprint);
        storage.append_wal(&header).context("write capture header")?;
        storage.sync_wal().context("sync capture header")?;
        Ok(Self {
            storage,
            fingerprint,
            next_seq: 1,
            records: 0,
            bytes: header.len() as u64,
            dead: false,
        })
    }

    /// Start a fresh capture at a file path (the `--capture-out`
    /// spelling): the parent directory becomes a [`FileStorage`] with
    /// the file's own name, so a registry in the same directory is
    /// never clobbered.
    pub fn create_at_path(path: impl AsRef<Path>, fingerprint: u64) -> Result<Self> {
        let path = path.as_ref();
        let (dir, name) = split_path(path)?;
        let storage = FileStorage::open_named(dir, name.clone(), format!("{name}.snap"))?;
        Self::create(Box::new(storage), fingerprint)
    }

    /// Append one record, assigning the next sequence number. Returns
    /// the framed byte length on success.
    pub fn append(&mut self, mut rec: CaptureRecord) -> Result<u64> {
        anyhow::ensure!(!self.dead, "capture log is dead after a failed write");
        rec.seq = self.next_seq;
        let bytes = codec::encode_record(&rec);
        if let Err(e) = self.storage.append_wal(&bytes) {
            self.dead = true;
            return Err(e.context("append capture record"));
        }
        self.next_seq += 1;
        self.records += 1;
        self.bytes += bytes.len() as u64;
        Ok(bytes.len() as u64)
    }

    /// Force appended records to stable storage.
    pub fn sync(&self) -> Result<()> {
        self.storage.sync_wal().context("sync capture log")
    }

    /// Records appended so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Bytes written so far (header included).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// The bundle fingerprint this capture is pinned to.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Replay a capture log out of any storage backend.
    pub fn load(storage: &dyn RegistryStorage) -> Result<CaptureReplay> {
        let bytes = storage.read_wal().context("read capture log")?;
        codec::replay_log(&bytes)
    }

    /// Replay a capture log from a file path.
    pub fn load_path(path: impl AsRef<Path>) -> Result<CaptureReplay> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("read capture log {}", path.display()))?;
        codec::replay_log(&bytes)
    }
}

fn split_path(path: &Path) -> Result<(std::path::PathBuf, String)> {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .filter(|n| !n.is_empty())
        .with_context(|| format!("capture path {} has no file name", path.display()))?;
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => std::path::PathBuf::from("."),
    };
    Ok((dir, name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceOutcome;
    use crate::serve::registry::{Fault, FaultInjector, MemStorage};

    fn rec(speaker: &str) -> CaptureRecord {
        CaptureRecord {
            seq: 0, // assigned by the log
            kind: RequestKind::Verify,
            speaker: speaker.into(),
            rows: 1,
            cols: 2,
            feats: vec![0.5, -0.5],
            arrival_offset_ns: 99,
            deadline_ms: 250,
            outcome: TraceOutcome::Ok,
            score: Some(2.5),
            spans: vec![],
        }
    }

    #[test]
    fn capture_log_round_trip_over_mem_storage() {
        let store = MemStorage::new();
        let mut log = CaptureLog::create(Box::new(store.clone()), 42).unwrap();
        log.append(rec("a")).unwrap();
        log.append(rec("b")).unwrap();
        log.sync().unwrap();
        assert_eq!(log.records(), 2);

        let rep = CaptureLog::load(&store).unwrap();
        assert_eq!(rep.fingerprint, 42);
        assert_eq!(rep.records.len(), 2);
        assert_eq!(rep.records[0].seq, 1);
        assert_eq!(rep.records[1].seq, 2);
        assert_eq!(rep.records[1].speaker, "b");
        assert!(!rep.torn_tail);
        assert_eq!(rep.valid_len, log.bytes());
    }

    #[test]
    fn capture_log_create_truncates_a_previous_session() {
        let store = MemStorage::new();
        let mut log = CaptureLog::create(Box::new(store.clone()), 1).unwrap();
        log.append(rec("old")).unwrap();
        drop(log);
        // a new session under a new bundle starts clean
        let log = CaptureLog::create(Box::new(store.clone()), 2).unwrap();
        drop(log);
        let rep = CaptureLog::load(&store).unwrap();
        assert_eq!(rep.fingerprint, 2);
        assert!(rep.records.is_empty());
    }

    #[test]
    fn capture_log_latches_dead_after_a_scripted_write_fault() {
        // the registry's deterministic fault injector applies verbatim:
        // storage op 4 (truncate, header append, sync, first record) is
        // the second record's append — script an ENOSPC there
        let store = MemStorage::new();
        let inj = FaultInjector::new(Box::new(store.clone())).fail_op(4, Fault::Enospc);
        let mut log = CaptureLog::create(Box::new(inj), 7).unwrap();
        log.append(rec("a")).unwrap();
        assert!(log.append(rec("b")).is_err(), "scripted ENOSPC must surface");
        // the log is latched: appending past a failed write would leave
        // mid-log garbage, so it must refuse
        assert!(log.append(rec("c")).is_err());
        assert_eq!(log.records(), 1);
        let rep = CaptureLog::load(&store).unwrap();
        assert_eq!(rep.records.len(), 1);
        assert_eq!(rep.records[0].speaker, "a");
    }

    #[test]
    fn capture_log_torn_write_recovers_to_the_intact_prefix() {
        let store = MemStorage::new();
        let inj =
            FaultInjector::new(Box::new(store.clone())).fail_op(4, Fault::TornWrite { keep: 5 });
        let mut log = CaptureLog::create(Box::new(inj), 7).unwrap();
        log.append(rec("a")).unwrap();
        let _ = log.append(rec("b")); // torn: only 5 bytes land
        let rep = CaptureLog::load(&store).unwrap();
        assert!(rep.torn_tail);
        assert_eq!(rep.records.len(), 1);
        assert_eq!(rep.records[0].speaker, "a");
    }

    #[test]
    fn capture_log_file_path_round_trip() {
        let dir = std::env::temp_dir().join(format!("ivcap-{}", std::process::id()));
        let path = dir.join("traffic.capture");
        let mut log = CaptureLog::create_at_path(&path, 11).unwrap();
        log.append(rec("x")).unwrap();
        log.sync().unwrap();
        drop(log);
        let rep = CaptureLog::load_path(&path).unwrap();
        assert_eq!(rep.fingerprint, 11);
        assert_eq!(rep.records.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
