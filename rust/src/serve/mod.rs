//! Online serving subsystem: micro-batched i-vector extraction, the
//! speaker registry, and the verification engine.
//!
//! The offline stack processes archives; this module turns the same
//! batched kernels into a long-lived request/response service — the
//! consequence of the paper's 3000×-real-time frame posteriors being
//! fast enough that *online* i-vector extraction is practical:
//!
//! * [`ModelBundle`] / [`ServeModel`] — the immutable model unit
//!   (UBM pair + total-variability model + LDA/PLDA backend) that
//!   [`Engine`] hot-swaps atomically;
//! * [`Engine`] — `extract` / `enroll` / `verify` over a dynamic
//!   micro-batcher: request threads do the CPU loader work (alignment,
//!   Baum-Welch statistics), worker threads drain the queue in
//!   `batch_utts`-sized model-coherent batches through the same
//!   [`crate::ivector::estep_batch_cpu`] GEMM path as training;
//! * [`ServeError`] — typed request failures: every request carries a
//!   submit deadline (admission control sheds with `Overloaded` when
//!   the queue stays full) and a request deadline (`Timeout` instead of
//!   a thread hung on a stalled worker);
//! * [`Registry`] — sharded-lock speaker store with enrollment
//!   averaging and `io`-format persistence (atomic snapshot writes);
//!   [`DurableRegistry`] layers an enrollment write-ahead log and
//!   crash-safe compaction underneath it ([`registry`]), behind the
//!   pluggable [`registry::RegistryStorage`] backend trait with a
//!   deterministic fault injector for crash drills;
//! * [`cluster`] — N engine replicas behind one [`cluster::Dispatcher`]
//!   sharing a single registry: load-aware routing, shed failover,
//!   rolling hot swaps, and self-healing supervision
//!   ([`cluster::health`]): per-replica error budgets quarantine a
//!   failing replica off the routing set, rebuild its engine, and
//!   restore it behind a circuit-breaker canary probe — while a
//!   WAL-poisoned registry degrades to read-only (verifies keep
//!   serving, enrolls fail typed) until repaired;
//! * [`session`] — streaming verification sessions: a [`StatAccum`]
//!   grown chunk by chunk against a model snapshot pinned at open,
//!   scored at any instant from partial stats (the same batched E-step
//!   path), with idle eviction, bounded admission, and configurable
//!   early-exit thresholds that decide before the utterance ends;
//! * [`bench`] — the load-replay harness behind `serve-bench` and the
//!   `BENCH_2.json` serving report (its cluster sibling lives in
//!   [`cluster::bench`] and writes `BENCH_5.json`);
//! * [`capture`] — the flight recorder: a durable, checksummed capture
//!   log of live requests (sampled at the engine or dispatcher, never
//!   on the request's critical path) and the deterministic replayer
//!   that re-issues a captured corpus against a fresh engine, verifies
//!   scores to 1e-10 when the bundle fingerprint matches, and writes
//!   the `BENCH_10.json` regression report.
//!
//! Every layer reports through [`crate::obs`]: canonical named
//! counters/histograms, per-request stage traces (admit-wait → align →
//! queue-wait → E-step → scoring, plus WAL append/fsync on durable
//! enrollments), and the slow-trace ring the `stats` CLI command reads.

pub mod bench;
pub mod capture;
pub mod cluster;
mod batcher;
mod bundle;
mod engine;
mod error;
pub mod registry;
pub mod session;

pub use bundle::{ModelBundle, ServeModel, StatAccum};
pub use capture::{CaptureLog, CaptureRecord, CaptureSummary, Recorder, RecorderOptions};
pub use cluster::{ClusterMetrics, Dispatcher, HealthState, ReplicaMetrics};
pub use engine::{Engine, EngineMetrics, VerifyOutcome};
pub use error::ServeError;
pub use session::{CloseReason, FeedOutcome, SessionManager};
pub use registry::{
    DurabilityMetrics, DurableRegistry, DurableRegistryOptions, RecoveryReport, Registry,
    SpeakerProfile,
};
