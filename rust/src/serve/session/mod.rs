//! Streaming verification sessions: per-caller incremental stat
//! accumulation with bounded admission and idle-deadline eviction.
//!
//! A session pins one model snapshot at open and grows a [`StatAccum`]
//! chunk by chunk — the paper's "alignment is cheap enough to run while
//! the speaker is still talking" observation turned into a serving
//! primitive. The manager here owns only the *state*: a sharded table
//! of sessions, a live-count admission bound (a session pins partial
//! stats plus an `Arc<ServeModel>`, so the table is memory admission
//! control, not bookkeeping), and the eviction sweep. The *ops* —
//! `open`/`feed`/`score`/`close`, which need the registry, the
//! micro-batcher, and the obs spans — live on
//! [`crate::serve::Engine`]; the cluster dispatcher adds the affinity
//! layer on top.
//!
//! Lifecycle is a one-way street: `Live` → `Closed(reason)`. A closed
//! session leaves a tombstone so later ops fail with the *typed* reason
//! ([`crate::serve::ServeError::SessionExpired`] vs
//! [`crate::serve::ServeError::SessionClosed`]) instead of a generic
//! "not found"; tombstones age out after two idle periods. Lock order
//! is always shard → session, and the sweep uses `try_lock` on session
//! state — a locked session is mid-op, which is the definition of "not
//! idle".

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::SessionConfig;
use crate::obs::{Counter, ObsRegistry};

use super::bundle::{ServeModel, StatAccum};
use super::error::ServeError;

/// Why a session stopped accepting ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CloseReason {
    /// Explicit close: the utterance ended and the final score was taken.
    Done,
    /// The idle-deadline sweep (or a lazy expiry check) reclaimed it.
    Expired,
    /// The early-exit policy finalized it before the utterance ended.
    EarlyExit,
}

/// What one `session_feed` produced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FeedOutcome {
    /// Chunk absorbed; no decision yet.
    Pending {
        /// Total frames accumulated so far.
        frames: usize,
    },
    /// The early-exit policy fired: the session is closed and this is
    /// its final verification decision.
    Decided {
        /// The deciding PLDA score.
        score: f64,
        /// Frames consumed to reach the decision.
        frames: usize,
        /// True = the accept threshold fired, false = the reject one.
        accepted: bool,
    },
}

/// One live session's mutable state, behind its own mutex so concurrent
/// feeds to the *same* session serialize without blocking the shard.
pub struct SessionState {
    /// Partial zeroth/first-order stats, grown per feed.
    pub(crate) accum: StatAccum,
    /// The model snapshot pinned at open: every feed aligns and every
    /// score finalizes against *this* snapshot, so a hot swap mid-
    /// session can never mix total-variability spaces.
    pub(crate) model: Arc<ServeModel>,
    /// The claimed speaker (profile looked up fresh at each score).
    pub(crate) speaker: String,
    /// Refreshed by every op; the idle sweep measures from here.
    pub(crate) last_active: Instant,
}

impl SessionState {
    /// Frames accumulated so far.
    pub fn frames(&self) -> usize {
        self.accum.frames()
    }

    /// The pinned model's fingerprint.
    pub fn fingerprint(&self) -> u64 {
        self.model.fingerprint
    }
}

enum Entry {
    Live(Arc<Mutex<SessionState>>),
    /// Tombstone: ops on a finalized/evicted id must fail typed, not as
    /// "not found". GC'd by the sweep after two idle periods.
    Closed { reason: CloseReason, at: Instant },
}

/// Sharded session table with bounded admission and idle eviction.
pub struct SessionManager {
    shards: Vec<Mutex<HashMap<u64, Entry>>>,
    /// Live (non-tombstone) sessions across all shards — the admission
    /// signal, maintained by open/close so admission never scans shards.
    live: AtomicUsize,
    next_id: AtomicU64,
    max_sessions: usize,
    idle: Duration,
    /// Sessions opened (`serve_sessions_opened_total`).
    opened: Counter,
    /// Early-exit finalizations (`serve_session_early_exits_total`).
    early_exits: Counter,
    /// Idle-deadline evictions (`serve_session_evictions_total`).
    evictions: Counter,
    /// Opens shed at the table bound (`serve_session_shed_total`).
    shed: Counter,
}

impl SessionManager {
    /// `obs`/`label` place the session counters next to the owning
    /// engine's other instruments (`name{engine="<label>"}`).
    pub fn new(cfg: &SessionConfig, obs: &ObsRegistry, label: &str) -> Self {
        let labels = [("engine", label)];
        Self {
            shards: (0..cfg.shards.max(1)).map(|_| Mutex::new(HashMap::new())).collect(),
            live: AtomicUsize::new(0),
            next_id: AtomicU64::new(1),
            max_sessions: cfg.max_sessions.max(1),
            idle: Duration::from_millis(cfg.idle_ms.max(1)),
            opened: obs.counter("serve_sessions_opened_total", &labels),
            early_exits: obs.counter("serve_session_early_exits_total", &labels),
            evictions: obs.counter("serve_session_evictions_total", &labels),
            shed: obs.counter("serve_session_shed_total", &labels),
        }
    }

    fn shard(&self, id: u64) -> &Mutex<HashMap<u64, Entry>> {
        &self.shards[(id as usize) % self.shards.len()]
    }

    /// The configured idle deadline.
    pub fn idle_deadline(&self) -> Duration {
        self.idle
    }

    /// Live sessions right now.
    pub fn live(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }

    /// Admit and create a session pinned to `model`, or shed typed
    /// ([`ServeError::SessionLimit`]) at the capacity bound.
    pub fn open(&self, speaker: String, model: Arc<ServeModel>) -> Result<u64> {
        // reserve the slot CAS-style so two racing opens cannot both
        // squeeze past the bound
        let mut n = self.live.load(Ordering::Acquire);
        loop {
            if n >= self.max_sessions {
                self.shed.inc();
                return Err(ServeError::SessionLimit { live: n }.into());
            }
            match self.live.compare_exchange(n, n + 1, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => break,
                Err(cur) => n = cur,
            }
        }
        let accum = model.stat_accum();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let state =
            SessionState { accum, model, speaker, last_active: Instant::now() };
        self.shard(id).lock().unwrap().insert(id, Entry::Live(Arc::new(Mutex::new(state))));
        self.opened.inc();
        Ok(id)
    }

    /// The live state behind `id`, or the typed reason it is gone.
    pub fn lookup(&self, id: u64) -> Result<Arc<Mutex<SessionState>>> {
        match self.shard(id).lock().unwrap().get(&id) {
            Some(Entry::Live(s)) => Ok(Arc::clone(s)),
            Some(Entry::Closed { reason: CloseReason::Expired, .. }) => {
                Err(ServeError::SessionExpired.into())
            }
            Some(Entry::Closed { .. }) => Err(ServeError::SessionClosed.into()),
            None => Err(ServeError::SessionNotFound.into()),
        }
    }

    /// Transition `id` Live → Closed(`reason`). Returns false if the
    /// session was already closed or never existed (two racing
    /// early-exit feeds: exactly one counts the close). A feed that
    /// raced past a concurrent close may still absorb into the orphaned
    /// state — harmless, it is dropped with the state.
    pub fn close(&self, id: u64, reason: CloseReason) -> bool {
        let mut shard = self.shard(id).lock().unwrap();
        if !matches!(shard.get(&id), Some(Entry::Live(_))) {
            return false;
        }
        shard.insert(id, Entry::Closed { reason, at: Instant::now() });
        self.live.fetch_sub(1, Ordering::AcqRel);
        match reason {
            CloseReason::Expired => self.evictions.inc(),
            CloseReason::EarlyExit => self.early_exits.inc(),
            CloseReason::Done => {}
        }
        true
    }

    /// Idle-deadline eviction plus tombstone GC. Cheap at the table's
    /// scale (a pointer walk per shard), so the engine runs it
    /// opportunistically on every open; returns the evicted count.
    pub fn sweep(&self) -> usize {
        let now = Instant::now();
        let mut evicted = 0;
        for shard in &self.shards {
            let mut shard = shard.lock().unwrap();
            shard.retain(|_, e| match e {
                Entry::Closed { at, .. } => now.saturating_duration_since(*at) < self.idle * 2,
                Entry::Live(_) => true,
            });
            let mut expired: Vec<u64> = Vec::new();
            for (id, e) in shard.iter() {
                if let Entry::Live(s) = e {
                    // a locked session is mid-op — not idle by definition
                    if let Ok(st) = s.try_lock() {
                        if now.saturating_duration_since(st.last_active) >= self.idle {
                            expired.push(*id);
                        }
                    }
                }
            }
            for id in expired {
                shard.insert(id, Entry::Closed { reason: CloseReason::Expired, at: now });
                self.live.fetch_sub(1, Ordering::AcqRel);
                self.evictions.inc();
                evicted += 1;
            }
        }
        evicted
    }

    /// Sessions opened so far.
    pub fn opened(&self) -> u64 {
        self.opened.get()
    }

    /// Early-exit finalizations so far.
    pub fn early_exits(&self) -> u64 {
        self.early_exits.get()
    }

    /// Idle-deadline evictions so far (sweep + lazy expiry).
    pub fn evictions(&self) -> u64 {
        self.evictions.get()
    }

    /// Opens shed at the capacity bound so far.
    pub fn shed_opens(&self) -> u64 {
        self.shed.get()
    }
}

/// The early-exit decision: `Some(accepted)` once a threshold fires,
/// `None` while the evidence is still inconclusive. Never fires below
/// `min_frames` — a partial-stat score on a handful of frames is noise.
pub fn early_exit_decision(cfg: &SessionConfig, frames: usize, score: f64) -> Option<bool> {
    if frames < cfg.min_frames {
        return None;
    }
    if let Some(t) = cfg.accept_score {
        if score >= t {
            return Some(true);
        }
    }
    if let Some(t) = cfg.reject_score {
        if score <= t {
            return Some(false);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::bench::shared_test_bundle;
    use super::*;

    fn model() -> Arc<ServeModel> {
        Arc::new(ServeModel::new(shared_test_bundle().clone()))
    }

    fn mgr(max_sessions: usize, idle_ms: u64) -> SessionManager {
        let cfg = SessionConfig { max_sessions, idle_ms, shards: 4, ..Default::default() };
        SessionManager::new(&cfg, &ObsRegistry::default(), "t")
    }

    #[test]
    fn session_admission_sheds_typed_at_the_bound() {
        let m = mgr(2, 60_000);
        let a = m.open("spk-a".into(), model()).unwrap();
        let b = m.open("spk-b".into(), model()).unwrap();
        assert_ne!(a, b, "ids are unique");
        assert_eq!(m.live(), 2);

        let err = m.open("spk-c".into(), model()).unwrap_err();
        let typed = err.downcast_ref::<ServeError>().expect("typed serve error");
        assert!(matches!(typed, ServeError::SessionLimit { live: 2 }), "{typed:?}");
        assert!(typed.is_rejection(), "a full table is load, not breakage");
        assert_eq!(m.shed_opens(), 1);

        // closing frees the slot; the tombstone answers typed
        assert!(m.close(a, CloseReason::Done));
        assert_eq!(m.live(), 1);
        m.open("spk-c".into(), model()).unwrap();
        let err = m.lookup(a).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionClosed)),
            "{err}"
        );
        let err = m.lookup(9_999).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionNotFound)),
            "{err}"
        );
        assert_eq!(m.opened(), 3);
    }

    #[test]
    fn session_close_counts_each_reason_exactly_once() {
        let m = mgr(8, 60_000);
        let a = m.open("a".into(), model()).unwrap();
        let b = m.open("b".into(), model()).unwrap();
        let c = m.open("c".into(), model()).unwrap();
        assert!(m.close(a, CloseReason::Done));
        assert!(m.close(b, CloseReason::EarlyExit));
        assert!(m.close(c, CloseReason::Expired));
        // a second close of any kind is a no-op, not a double count
        assert!(!m.close(b, CloseReason::EarlyExit));
        assert!(!m.close(c, CloseReason::Done));
        assert_eq!(m.early_exits(), 1);
        assert_eq!(m.evictions(), 1);
        assert_eq!(m.live(), 0);
    }

    #[test]
    fn session_sweep_evicts_idle_and_ages_out_tombstones() {
        let m = mgr(8, 40);
        let stale = m.open("stale".into(), model()).unwrap();
        let fresh = m.open("fresh".into(), model()).unwrap();
        std::thread::sleep(Duration::from_millis(55));
        // one session stays active (an op refreshes last_active)...
        m.lookup(fresh).unwrap().lock().unwrap().last_active = Instant::now();
        // ...the other idles past the deadline and is reclaimed
        assert_eq!(m.sweep(), 1);
        assert_eq!(m.live(), 1);
        assert_eq!(m.evictions(), 1);
        let err = m.lookup(stale).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionExpired)),
            "{err}"
        );
        m.lookup(fresh).expect("the refreshed session survives the sweep");

        // a mid-op (locked) session is never evicted, however old its
        // last_active stamp looks from outside
        {
            let s = m.lookup(fresh).unwrap();
            let mut st = s.lock().unwrap();
            st.last_active = Instant::now() - Duration::from_millis(500);
            assert_eq!(m.sweep(), 0, "locked session must be skipped");
            st.last_active = Instant::now();
        }

        // tombstones age out after two idle periods → typed NotFound
        std::thread::sleep(Duration::from_millis(90));
        m.lookup(fresh).unwrap().lock().unwrap().last_active = Instant::now();
        m.sweep();
        let err = m.lookup(stale).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionNotFound)),
            "aged-out tombstone: {err}"
        );
    }

    #[test]
    fn early_exit_policy_respects_min_frames_and_thresholds() {
        let cfg = SessionConfig {
            min_frames: 50,
            accept_score: Some(2.0),
            reject_score: Some(-1.0),
            ..Default::default()
        };
        // below min_frames nothing fires, however confident the score
        assert_eq!(early_exit_decision(&cfg, 10, 99.0), None);
        assert_eq!(early_exit_decision(&cfg, 49, -99.0), None);
        // at/above it, thresholds decide; the gap stays pending
        assert_eq!(early_exit_decision(&cfg, 50, 2.0), Some(true));
        assert_eq!(early_exit_decision(&cfg, 50, -1.0), Some(false));
        assert_eq!(early_exit_decision(&cfg, 120, 0.5), None);
        // disabled thresholds never fire
        let off = SessionConfig { min_frames: 0, ..Default::default() };
        assert_eq!(early_exit_decision(&off, 1_000, 99.0), None);
        // accept-only config cannot reject
        let acc = SessionConfig {
            min_frames: 0,
            accept_score: Some(1.0),
            reject_score: None,
            ..Default::default()
        };
        assert_eq!(early_exit_decision(&acc, 100, -99.0), None);
        assert_eq!(early_exit_decision(&acc, 100, 1.5), Some(true));
    }
}
