//! Serving-load harness: train a tiny in-process bundle, replay
//! synthetic enroll/verify traffic against an [`Engine`] at a given
//! concurrency, and report latency/throughput — the machinery behind
//! the `serve-bench` CLI command and the `speed_report` example's
//! `BENCH_2.json` serving section.
//!
//! The streaming half ([`run_streaming_vs_oneshot`]) replays the same
//! trial plan through chunk-fed sessions with calibrated early-exit
//! thresholds and writes the `BENCH_8.json` comparison: mean frames
//! consumed per verify decision against the one-shot baseline that
//! must always ingest the whole utterance.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::backend::{Backend, BackendOpts};
use crate::bench_util::{variants_json, write_bench_json};
use crate::config::{Config, ObsConfig};
use crate::coordinator::{align_archive_cpu_prec, stats_from_posts, ComputePath, TrainSetup};
use crate::exec::default_workers;
use crate::frontend::synth::{generate_corpus, TrafficGen};
use crate::ivector::{extract_cpu, Formulation, TrainVariant, UttStats};
use crate::linalg::Mat;
use crate::metrics::{LatencySummary, Stopwatch};
use crate::obs::{latency_summary_json, ObsRegistry};

use super::bundle::{ModelBundle, ServeModel};
use super::engine::Engine;
use super::error::ServeError;
use super::registry::Registry;
use super::session::FeedOutcome;

/// A scaled-down config whose full offline recipe trains in seconds —
/// the "tiny-config engine" of the serving benchmarks and tests.
pub fn tiny_serve_config() -> Config {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 8;
    cfg.corpus.utts_per_train_speaker = 5;
    cfg.corpus.n_eval_speakers = 2;
    cfg.corpus.utts_per_eval_speaker = 2;
    cfg.corpus.min_frames = 60;
    cfg.corpus.max_frames = 100;
    cfg.corpus.base_dim = 3;
    cfg.corpus.true_components = 6;
    cfg.corpus.speaker_rank = 4;
    cfg.corpus.channel_rank = 2;
    cfg.ubm.components = 8;
    cfg.ubm.diag_em_iters = 2;
    cfg.ubm.full_em_iters = 1;
    cfg.ubm.train_frames = 4000;
    cfg.tvm.rank = 8;
    cfg.tvm.iters = 2;
    cfg.tvm.top_k = 4;
    cfg.tvm.batch_utts = 16;
    cfg.backend.lda_dim = 4;
    cfg.backend.plda_iters = 3;
    cfg
}

/// Deterministic serving-traffic source at a config's corpus dims.
pub fn tiny_traffic(cfg: &Config, n_speakers: usize, seed: u64) -> TrafficGen {
    TrafficGen::new(&cfg.corpus, n_speakers, seed)
}

/// One tiny bundle shared across the serve/cluster tests (training it
/// takes a few seconds; every test wants the same deterministic model,
/// so train it exactly once per test binary).
#[cfg(test)]
pub(crate) fn shared_test_bundle() -> &'static ModelBundle {
    static BUNDLE: std::sync::OnceLock<ModelBundle> = std::sync::OnceLock::new();
    BUNDLE.get_or_init(|| train_tiny_bundle(&tiny_serve_config(), 5).unwrap())
}

/// The deterministic verify-trial plan shared by the engine and
/// cluster load harnesses: request `i` claims speaker `i % n_spk`;
/// even requests are target trials, odd ones impostor trials voiced by
/// the next speaker. Returns `(claimed, actual, is_target)`.
pub(crate) fn trial_plan(i: usize, n_spk: usize) -> (usize, usize, bool) {
    let claimed = i % n_spk;
    let target = i % 2 == 0;
    let actual = if target { claimed } else { (claimed + 1) % n_spk };
    (claimed, actual, target)
}

/// Run the full offline recipe in-process (synth → UBM → extractor →
/// backend) and assemble the serving bundle. At [`tiny_serve_config`]
/// dims this takes seconds, which is what lets `serve-bench` and the
/// serve tests run standalone, without a pre-trained work dir.
pub fn train_tiny_bundle(cfg: &Config, seed: u64) -> Result<ModelBundle> {
    let workers = default_workers();
    let corpus = generate_corpus(&cfg.corpus)?;
    let (ubm, _) = crate::gmm::train_ubm(&corpus.train, &cfg.ubm, seed)?;
    let mut setup = TrainSetup { cfg, feats: &corpus.train, diag: ubm.diag, full: ubm.full };
    let variant = TrainVariant {
        formulation: Formulation::Augmented,
        min_divergence: true,
        sigma_update: false,
        realign_every: None,
    };
    let (tvm, _) = crate::coordinator::train_tvm(
        &mut setup,
        variant,
        cfg.tvm.iters,
        seed,
        ComputePath::CpuRef,
        None,
        &mut |_| None,
    )?;
    // backend on the training i-vectors (same `[align] precision` as
    // the extractor training above — one regime per bundle)
    let posts = align_archive_cpu_prec(
        &setup.diag,
        &setup.full,
        &corpus.train,
        cfg.tvm.top_k,
        cfg.tvm.min_post,
        workers,
        cfg.align.precision,
    );
    let (bw, _) = stats_from_posts(&corpus.train, &posts, cfg.ubm.components, workers);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &tvm)).collect();
    let ivecs = extract_cpu(&tvm, &utts, workers);
    let spk_ids: Vec<String> = corpus.train.utts.iter().map(|u| u.spk_id.clone()).collect();
    let labels = crate::coordinator::stages::dense_labels(&spk_ids);
    let backend = Backend::train(
        &ivecs,
        &labels,
        &BackendOpts { lda_dim: cfg.backend.lda_dim, plda_iters: cfg.backend.plda_iters, whiten: false },
    )?;
    Ok(ModelBundle {
        diag: setup.diag,
        full: setup.full,
        tvm,
        backend,
        top_k: cfg.tvm.top_k,
        min_post: cfg.tvm.min_post,
    })
}

/// Load-replay parameters.
#[derive(Debug, Clone)]
pub struct ServeBenchOpts {
    /// Speakers enrolled before the load phase.
    pub speakers: usize,
    /// Enrollment utterances per speaker.
    pub enroll_utts: usize,
    /// Verify requests replayed (half target, half impostor trials).
    pub requests: usize,
    /// Concurrent client threads.
    pub concurrency: usize,
}

/// One load run's results.
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    /// Requests attempted.
    pub requests: usize,
    /// Requests that produced a score (attempted minus shed/timed-out).
    pub completed_requests: usize,
    pub concurrency: usize,
    pub wall_s: f64,
    /// Completed requests per second — rejections do no E-step work, so
    /// counting them would let an aggressively-shedding engine report
    /// *higher* throughput under saturation.
    pub throughput_rps: f64,
    pub verify: LatencySummary,
    pub enroll: LatencySummary,
    pub dispatched_batches: u64,
    pub batched_requests: u64,
    /// Mean requests per dispatched E-step batch (from
    /// [`crate::serve::EngineMetrics::mean_batch`]).
    pub mean_batch: f64,
    /// Requests shed at admission (typed `Overloaded` rejections).
    pub shed_requests: u64,
    /// Requests that missed their response deadline (typed `Timeout`).
    pub timed_out_requests: u64,
    /// Largest micro-batch queue depth an admitted request saw.
    pub queue_depth_max: u64,
    /// Mean post-enqueue queue depth over admitted requests.
    pub queue_depth_mean: f64,
    /// Registry WAL records appended during the run (0 on a volatile
    /// registry).
    pub wal_appends: u64,
    /// Registry compactions (WAL → snapshot) completed during the run.
    pub compactions: u64,
    /// Torn WAL tails detected when the engine's registry was opened
    /// (nonzero means this run started from a crash recovery).
    pub torn_tail: u64,
    pub target_mean: f64,
    pub impostor_mean: f64,
    /// Per-stage latency summaries (admit-wait, align, queue-wait,
    /// E-step, …) from the engine's [`ObsRegistry`] — where a slow
    /// p99 actually went.
    pub stages: Vec<(&'static str, LatencySummary)>,
}

impl ServeBenchReport {
    /// One JSON object (no trailing newline) for the BENCH_2 report.
    pub fn json_fragment(&self) -> String {
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|(name, s)| format!("\"{name}\": {}", latency_summary_json(s)))
            .collect();
        format!(
            "{{\"requests\": {}, \"completed\": {}, \"concurrency\": {}, \"wall_s\": {:.6}, \
\"throughput_rps\": {:.2}, \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \"p99_ms\": {:.4}, \
\"mean_ms\": {:.4}, \"max_ms\": {:.4}, \"mean_batch\": {:.3}, \
\"shed\": {}, \"timeouts\": {}, \"queue_depth_max\": {}, \"queue_depth_mean\": {:.2}, \
\"wal_appends\": {}, \"compactions\": {}, \"torn_tail\": {}, \
\"target_mean_score\": {:.4}, \"impostor_mean_score\": {:.4}, \"stages\": {{{}}}}}",
            self.requests,
            self.completed_requests,
            self.concurrency,
            self.wall_s,
            self.throughput_rps,
            self.verify.p50_s * 1e3,
            self.verify.p95_s * 1e3,
            self.verify.p99_s * 1e3,
            self.verify.mean_s * 1e3,
            self.verify.max_s * 1e3,
            self.mean_batch,
            self.shed_requests,
            self.timed_out_requests,
            self.queue_depth_max,
            self.queue_depth_mean,
            self.wal_appends,
            self.compactions,
            self.torn_tail,
            self.target_mean,
            self.impostor_mean,
            stages.join(", "),
        )
    }
}

/// Per-client accumulator of a load run: score sums plus the
/// deadline-driven rejections (shed/timeout) the client absorbed.
#[derive(Debug, Default, Clone, Copy)]
struct ClientAcc {
    target_sum: f64,
    target_n: usize,
    impostor_sum: f64,
    impostor_n: usize,
    rejected: usize,
}

/// Enroll `opts.speakers` from the traffic source, then replay
/// `opts.requests` verify requests from `opts.concurrency` client
/// threads (alternating target and impostor trials). Expects a fresh
/// engine — its latency histograms become the report.
///
/// Typed admission rejections ([`ServeError::Overloaded`] /
/// [`ServeError::Timeout`]) are *counted, not propagated*: under
/// deliberate saturation the harness must keep driving load to observe
/// the shed behaviour it is there to measure. Any other error still
/// aborts the run.
pub fn run_verify_load(
    engine: &Engine,
    traffic: &TrafficGen,
    opts: &ServeBenchOpts,
) -> Result<ServeBenchReport> {
    let n_spk = opts.speakers.min(traffic.n_speakers());
    // with one speaker, "impostor" trials would silently score the
    // claimed speaker against itself — refuse rather than mislead
    anyhow::ensure!(
        n_spk >= 2,
        "verify load needs at least 2 speakers for impostor trials (got {n_spk})"
    );
    for s in 0..n_spk {
        let id = traffic.speaker_id(s);
        for k in 0..opts.enroll_utts.max(1) {
            engine.enroll(&id, &traffic.utterance(s, k as u64))?;
        }
    }

    let sw = Stopwatch::start();
    let concurrency = opts.concurrency.max(1);
    let partials: Result<Vec<ClientAcc>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency)
            .map(|c| {
                scope.spawn(move || -> Result<ClientAcc> {
                    let mut acc = ClientAcc::default();
                    let mut i = c;
                    while i < opts.requests {
                        let (claimed, actual, target) = trial_plan(i, n_spk);
                        // verification keys live past the enrollment keys
                        let feats = traffic.utterance(actual, 1_000 + i as u64);
                        match engine.verify(&traffic.speaker_id(claimed), &feats) {
                            Ok(out) if target => {
                                acc.target_sum += out.score;
                                acc.target_n += 1;
                            }
                            Ok(out) => {
                                acc.impostor_sum += out.score;
                                acc.impostor_n += 1;
                            }
                            Err(e)
                                if e.downcast_ref::<ServeError>()
                                    .is_some_and(ServeError::is_rejection) =>
                            {
                                acc.rejected += 1;
                            }
                            Err(e) => return Err(e),
                        }
                        i += concurrency;
                    }
                    Ok(acc)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    let partials = partials.context("verify load failed")?;
    let wall_s = sw.elapsed_s();

    let mut total = ClientAcc::default();
    for p in partials {
        total.target_sum += p.target_sum;
        total.target_n += p.target_n;
        total.impostor_sum += p.impostor_sum;
        total.impostor_n += p.impostor_n;
        total.rejected += p.rejected;
    }
    if total.rejected > 0 {
        println!(
            "verify load: {} of {} requests rejected under overload (shed or timed out)",
            total.rejected, opts.requests
        );
    }
    let m = engine.metrics();
    let completed = opts.requests - total.rejected;
    Ok(ServeBenchReport {
        requests: opts.requests,
        completed_requests: completed,
        concurrency,
        wall_s,
        throughput_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { f64::INFINITY },
        verify: m.verify,
        enroll: m.enroll,
        dispatched_batches: m.dispatched_batches,
        batched_requests: m.batched_requests,
        mean_batch: m.mean_batch(),
        shed_requests: m.shed_requests,
        timed_out_requests: m.timed_out_requests,
        queue_depth_max: m.queue_depth.max,
        queue_depth_mean: m.queue_depth.mean,
        wal_appends: m.durability.wal_appends,
        compactions: m.durability.compactions,
        torn_tail: m.durability.torn_tail,
        target_mean: if total.target_n > 0 {
            total.target_sum / total.target_n as f64
        } else {
            0.0
        },
        impostor_mean: if total.impostor_n > 0 {
            total.impostor_sum / total.impostor_n as f64
        } else {
            0.0
        },
        stages: engine.obs().stage_summaries(),
    })
}

/// Run the same load twice — once through `serve_cfg` (micro-batching
/// on) and once through a `batch_utts = 1` twin — the comparison the
/// `serve-bench` CLI and the `speed_report` example both report.
///
/// Each engine gets its own [`ObsRegistry`] built from `obs_cfg` so
/// the two variants' stage histograms stay separate; the batched
/// engine's registry is returned for snapshot export (`--obs-out`).
pub fn run_batched_vs_unbatched(
    bundle: ModelBundle,
    serve_cfg: &crate::config::ServeConfig,
    obs_cfg: &ObsConfig,
    traffic: &TrafficGen,
    opts: &ServeBenchOpts,
) -> Result<(ServeBenchReport, ServeBenchReport, Arc<ObsRegistry>)> {
    let obs = Arc::new(ObsRegistry::new(obs_cfg));
    let batched = {
        let engine = Engine::with_registry_obs(
            bundle.clone(),
            serve_cfg,
            Arc::new(Registry::new(serve_cfg.registry_shards)),
            Arc::clone(&obs),
        )?;
        run_verify_load(&engine, traffic, opts)?
    };
    let unbatched = {
        let mut solo = serve_cfg.clone();
        solo.batch_utts = 1;
        let engine = Engine::with_registry_obs(
            bundle,
            &solo,
            Arc::new(Registry::new(solo.registry_shards)),
            Arc::new(ObsRegistry::new(obs_cfg)),
        )?;
        run_verify_load(&engine, traffic, opts)?
    };
    Ok((batched, unbatched, obs))
}

/// Streaming-session load parameters (the `serve-bench --streaming`
/// mode).
#[derive(Debug, Clone)]
pub struct StreamBenchOpts {
    /// Speakers enrolled before the load phase.
    pub speakers: usize,
    /// Enrollment utterances per speaker.
    pub enroll_utts: usize,
    /// Streaming verification sessions replayed (the same alternating
    /// target/impostor [`trial_plan`] as the one-shot load).
    pub requests: usize,
    /// Concurrent client threads.
    pub concurrency: usize,
    /// Frames per `session_feed` chunk.
    pub chunk_frames: usize,
    /// Early-exit thresholds. `None` = calibrate from serial oracle
    /// probe trials ([`calibrate_thresholds`]).
    pub accept_score: Option<f64>,
    pub reject_score: Option<f64>,
}

/// One streaming load run's results.
#[derive(Debug, Clone)]
pub struct StreamBenchReport {
    /// Sessions attempted.
    pub requests: usize,
    /// Sessions that reached a verification decision — by early exit
    /// or by the close-time score.
    pub decided: usize,
    /// Sessions lost to typed backpressure (shed opens, overload or
    /// timeout on the scoring path, idle eviction) — counted, never a
    /// hard failure.
    pub rejected: usize,
    pub concurrency: usize,
    pub chunk_frames: usize,
    pub wall_s: f64,
    pub decisions_per_s: f64,
    /// The headline: mean frames consumed per decision. Early exits
    /// stop listening mid-utterance, so under calibrated thresholds
    /// this lands below [`StreamBenchReport::mean_frames_available`].
    pub mean_frames_per_decision: f64,
    /// Mean frames the full utterances offered — exactly what the
    /// one-shot path must ingest for the same trials.
    pub mean_frames_available: f64,
    /// Decisions delivered by the early-exit policy (client view; the
    /// engine's `session_early_exits` counter tells the same story).
    pub early_exits: usize,
    /// `early_exits / decided`.
    pub early_exit_rate: f64,
    /// The thresholds the run actually used (calibrated or explicit).
    pub accept_score: f64,
    pub reject_score: f64,
    pub sessions_opened: u64,
    /// Engine-side idle evictions during the run.
    pub evictions: u64,
    /// Engine-side shed session opens (typed `SessionLimit`).
    pub shed: u64,
    pub target_mean: f64,
    pub impostor_mean: f64,
    /// Per-stage latency summaries, now including `session_feed` and
    /// `session_score`.
    pub stages: Vec<(&'static str, LatencySummary)>,
}

impl StreamBenchReport {
    /// One JSON object (no trailing newline) for the BENCH_8 report.
    pub fn json_fragment(&self) -> String {
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|(name, s)| format!("\"{name}\": {}", latency_summary_json(s)))
            .collect();
        format!(
            "{{\"requests\": {}, \"decided\": {}, \"rejected\": {}, \"concurrency\": {}, \
\"chunk_frames\": {}, \"wall_s\": {:.6}, \"decisions_per_s\": {:.2}, \
\"mean_frames_per_decision\": {:.2}, \"mean_frames_available\": {:.2}, \
\"early_exits\": {}, \"early_exit_rate\": {:.4}, \
\"accept_score\": {:.4}, \"reject_score\": {:.4}, \
\"sessions_opened\": {}, \"evictions\": {}, \"shed\": {}, \
\"target_mean_score\": {:.4}, \"impostor_mean_score\": {:.4}, \"stages\": {{{}}}}}",
            self.requests,
            self.decided,
            self.rejected,
            self.concurrency,
            self.chunk_frames,
            self.wall_s,
            self.decisions_per_s,
            self.mean_frames_per_decision,
            self.mean_frames_available,
            self.early_exits,
            self.early_exit_rate,
            self.accept_score,
            self.reject_score,
            self.sessions_opened,
            self.evictions,
            self.shed,
            self.target_mean,
            self.impostor_mean,
            stages.join(", "),
        )
    }
}

/// Copy rows `[lo, hi)` of an utterance into a standalone chunk — the
/// shape a streaming client hands `session_feed`.
pub(crate) fn chunk_rows(feats: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, feats.cols(), |t, j| feats.get(lo + t, j))
}

/// Calibrate early-exit thresholds from serial-oracle probe trials:
/// accept fires at `impostor_mean + 0.75·gap`, reject at `+0.25·gap`
/// (gap = target mean − impostor mean). Both sit strictly inside the
/// score gap, so confident trials exit as soon as `min_frames` allows
/// while genuinely ambiguous ones run to the end of the utterance.
pub fn calibrate_thresholds(
    bundle: &ModelBundle,
    traffic: &TrafficGen,
    n_spk: usize,
    enroll_utts: usize,
    probes: usize,
) -> (f64, f64) {
    let oracle = ServeModel::new(bundle.clone());
    let enroll_utts = enroll_utts.max(1);
    let means: Vec<Vec<f64>> = (0..n_spk)
        .map(|s| {
            let mut sum = vec![0.0; oracle.rank()];
            for k in 0..enroll_utts {
                let iv = oracle.extract_serial(&traffic.utterance(s, k as u64));
                for (a, x) in sum.iter_mut().zip(&iv) {
                    *a += x;
                }
            }
            sum.iter().map(|&x| x / enroll_utts as f64).collect()
        })
        .collect();
    let (mut t_sum, mut t_n, mut i_sum, mut i_n) = (0.0f64, 0usize, 0.0f64, 0usize);
    for i in 0..probes.max(2) {
        let (claimed, actual, target) = trial_plan(i, n_spk);
        // probe keys live between the enrollment keys and the load keys
        let iv = oracle.extract_serial(&traffic.utterance(actual, 500 + i as u64));
        let score = oracle.score(&means[claimed], &iv);
        if target {
            t_sum += score;
            t_n += 1;
        } else {
            i_sum += score;
            i_n += 1;
        }
    }
    let tm = t_sum / t_n.max(1) as f64;
    let im = i_sum / i_n.max(1) as f64;
    let gap = tm - im;
    (im + 0.75 * gap, im + 0.25 * gap)
}

/// True for the typed errors a streaming client under load absorbs and
/// counts: admission sheds and deadline misses on the scoring path,
/// session-table sheds at open, and idle eviction mid-session. Anything
/// else is a harness failure and aborts the run.
fn typed_backpressure(e: &anyhow::Error) -> bool {
    matches!(
        e.downcast_ref::<ServeError>(),
        Some(
            ServeError::Overloaded { .. }
                | ServeError::Timeout { .. }
                | ServeError::SessionLimit { .. }
                | ServeError::SessionExpired
        )
    )
}

/// Drive one session to a decision: open, feed fixed-size chunks until
/// the early-exit policy fires, and close for the final score when it
/// never does. Returns `(score, frames_consumed, early_exit)`, or
/// `None` on typed backpressure (the engine's idle sweep reclaims any
/// session abandoned mid-feed).
fn drive_session(
    engine: &Engine,
    speaker: &str,
    feats: &Mat,
    chunk_frames: usize,
) -> Result<Option<(f64, usize, bool)>> {
    let sid = match engine.session_open(speaker) {
        Ok(s) => s,
        Err(e) if typed_backpressure(&e) => return Ok(None),
        Err(e) => return Err(e),
    };
    let rows = feats.rows();
    let mut lo = 0;
    while lo < rows {
        let hi = (lo + chunk_frames).min(rows);
        match engine.session_feed(sid, &chunk_rows(feats, lo, hi)) {
            Ok(FeedOutcome::Pending { .. }) => {}
            Ok(FeedOutcome::Decided { score, frames, .. }) => {
                return Ok(Some((score, frames, true)))
            }
            Err(e) if typed_backpressure(&e) => return Ok(None),
            Err(e) => return Err(e),
        }
        lo = hi;
    }
    match engine.session_close(sid) {
        Ok(out) => Ok(Some((out.score, rows, false))),
        Err(e) if typed_backpressure(&e) => Ok(None),
        Err(e) => Err(e),
    }
}

/// Per-client accumulator of a streaming run.
#[derive(Debug, Default, Clone, Copy)]
struct StreamAcc {
    frames_consumed: u64,
    frames_available: u64,
    decided: usize,
    early_exits: usize,
    rejected: usize,
    target_sum: f64,
    target_n: usize,
    impostor_sum: f64,
    impostor_n: usize,
}

/// Enroll `opts.speakers`, then replay `opts.requests` streaming
/// sessions from `opts.concurrency` client threads — the chunk-fed
/// twin of [`run_verify_load`], over the same [`trial_plan`]. The
/// engine must already carry the early-exit thresholds in its
/// `[session]` config; they are passed in again only for the report.
pub fn run_streaming_load(
    engine: &Engine,
    traffic: &TrafficGen,
    opts: &StreamBenchOpts,
    accept_score: f64,
    reject_score: f64,
) -> Result<StreamBenchReport> {
    let n_spk = opts.speakers.min(traffic.n_speakers());
    anyhow::ensure!(
        n_spk >= 2,
        "streaming load needs at least 2 speakers for impostor trials (got {n_spk})"
    );
    for s in 0..n_spk {
        let id = traffic.speaker_id(s);
        for k in 0..opts.enroll_utts.max(1) {
            engine.enroll(&id, &traffic.utterance(s, k as u64))?;
        }
    }
    let chunk_frames = opts.chunk_frames.max(1);
    let sw = Stopwatch::start();
    let concurrency = opts.concurrency.max(1);
    let partials: Result<Vec<StreamAcc>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..concurrency)
            .map(|c| {
                scope.spawn(move || -> Result<StreamAcc> {
                    let mut acc = StreamAcc::default();
                    let mut i = c;
                    while i < opts.requests {
                        let (claimed, actual, target) = trial_plan(i, n_spk);
                        // session keys live past both the enrollment
                        // keys and the one-shot load's 1_000+ keys
                        let feats = traffic.utterance(actual, 10_000 + i as u64);
                        acc.frames_available += feats.rows() as u64;
                        let id = traffic.speaker_id(claimed);
                        match drive_session(engine, &id, &feats, chunk_frames)? {
                            Some((score, frames, early)) => {
                                acc.decided += 1;
                                acc.frames_consumed += frames as u64;
                                if early {
                                    acc.early_exits += 1;
                                }
                                if target {
                                    acc.target_sum += score;
                                    acc.target_n += 1;
                                } else {
                                    acc.impostor_sum += score;
                                    acc.impostor_n += 1;
                                }
                            }
                            None => acc.rejected += 1,
                        }
                        i += concurrency;
                    }
                    Ok(acc)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    let partials = partials.context("streaming load failed")?;
    let wall_s = sw.elapsed_s();

    let mut t = StreamAcc::default();
    for p in partials {
        t.frames_consumed += p.frames_consumed;
        t.frames_available += p.frames_available;
        t.decided += p.decided;
        t.early_exits += p.early_exits;
        t.rejected += p.rejected;
        t.target_sum += p.target_sum;
        t.target_n += p.target_n;
        t.impostor_sum += p.impostor_sum;
        t.impostor_n += p.impostor_n;
    }
    if t.rejected > 0 {
        println!(
            "streaming load: {} of {} sessions lost to typed backpressure",
            t.rejected, opts.requests
        );
    }
    let m = engine.metrics();
    Ok(StreamBenchReport {
        requests: opts.requests,
        decided: t.decided,
        rejected: t.rejected,
        concurrency,
        chunk_frames,
        wall_s,
        decisions_per_s: if wall_s > 0.0 { t.decided as f64 / wall_s } else { f64::INFINITY },
        mean_frames_per_decision: t.frames_consumed as f64 / t.decided.max(1) as f64,
        mean_frames_available: t.frames_available as f64 / opts.requests.max(1) as f64,
        early_exits: t.early_exits,
        early_exit_rate: t.early_exits as f64 / t.decided.max(1) as f64,
        accept_score,
        reject_score,
        sessions_opened: m.sessions_opened,
        evictions: m.session_evictions,
        shed: m.session_shed,
        target_mean: if t.target_n > 0 { t.target_sum / t.target_n as f64 } else { 0.0 },
        impostor_mean: if t.impostor_n > 0 { t.impostor_sum / t.impostor_n as f64 } else { 0.0 },
        stages: engine.obs().stage_summaries(),
    })
}

/// Run the streaming-session load and the one-shot baseline on twin
/// engines over the same traffic source — the `serve-bench --streaming`
/// comparison. Thresholds come from the opts when given, otherwise
/// from [`calibrate_thresholds`]; the streaming engine's registry is
/// returned for snapshot export (`--obs-out`).
pub fn run_streaming_vs_oneshot(
    bundle: ModelBundle,
    serve_cfg: &crate::config::ServeConfig,
    obs_cfg: &ObsConfig,
    traffic: &TrafficGen,
    opts: &StreamBenchOpts,
) -> Result<(StreamBenchReport, ServeBenchReport, Arc<ObsRegistry>)> {
    let n_spk = opts.speakers.min(traffic.n_speakers()).max(2);
    let (auto_accept, auto_reject) =
        calibrate_thresholds(&bundle, traffic, n_spk, opts.enroll_utts, 32);
    let accept = opts.accept_score.unwrap_or(auto_accept);
    let reject = opts.reject_score.unwrap_or(auto_reject);
    let mut streaming_cfg = serve_cfg.clone();
    streaming_cfg.session.accept_score = Some(accept);
    streaming_cfg.session.reject_score = Some(reject);
    let obs = Arc::new(ObsRegistry::new(obs_cfg));
    let streaming = {
        let engine = Engine::with_registry_obs(
            bundle.clone(),
            &streaming_cfg,
            Arc::new(Registry::new(streaming_cfg.registry_shards)),
            Arc::clone(&obs),
        )?;
        run_streaming_load(&engine, traffic, opts, accept, reject)?
    };
    let oneshot = {
        let engine = Engine::with_registry_obs(
            bundle,
            serve_cfg,
            Arc::new(Registry::new(serve_cfg.registry_shards)),
            Arc::new(ObsRegistry::new(obs_cfg)),
        )?;
        let base = ServeBenchOpts {
            speakers: opts.speakers,
            enroll_utts: opts.enroll_utts,
            requests: opts.requests,
            concurrency: opts.concurrency,
        };
        run_verify_load(&engine, traffic, &base)?
    };
    Ok((streaming, oneshot, obs))
}

/// Write the `BENCH_8.json` streaming report: the session run next to
/// its one-shot baseline over the same trial plan.
pub fn write_bench8_json(
    path: impl AsRef<Path>,
    streaming: &StreamBenchReport,
    oneshot: &ServeBenchReport,
) -> Result<()> {
    let runs = vec![
        ("streaming".to_string(), streaming.json_fragment()),
        ("oneshot".to_string(), oneshot.json_fragment()),
    ];
    write_bench_json(path, 8, &[("sessions", variants_json(&runs))])
}

/// Write the `BENCH_2.json` serving report from named load runs.
pub fn write_bench2_json(
    path: impl AsRef<Path>,
    variants: &[(&str, &ServeBenchReport)],
) -> Result<()> {
    let runs: Vec<(String, String)> =
        variants.iter().map(|(name, r)| (name.to_string(), r.json_fragment())).collect();
    write_bench_json(path, 2, &[("serving", variants_json(&runs))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_report_json_shape() {
        let report = ServeBenchReport {
            requests: 100,
            completed_requests: 96,
            concurrency: 4,
            wall_s: 0.5,
            throughput_rps: 200.0,
            verify: LatencySummary {
                count: 100,
                invalid: 0,
                mean_s: 0.002,
                p50_s: 0.0015,
                p95_s: 0.004,
                p99_s: 0.006,
                max_s: 0.008,
            },
            enroll: LatencySummary {
                count: 8,
                invalid: 0,
                mean_s: 0.002,
                p50_s: 0.0015,
                p95_s: 0.004,
                p99_s: 0.006,
                max_s: 0.008,
            },
            dispatched_batches: 25,
            batched_requests: 100,
            mean_batch: 4.0,
            shed_requests: 3,
            timed_out_requests: 1,
            queue_depth_max: 12,
            queue_depth_mean: 4.5,
            wal_appends: 8,
            compactions: 1,
            torn_tail: 0,
            target_mean: 3.0,
            impostor_mean: -2.0,
            stages: vec![(
                "align",
                LatencySummary {
                    count: 100,
                    invalid: 0,
                    mean_s: 0.001,
                    p50_s: 0.001,
                    p95_s: 0.002,
                    p99_s: 0.003,
                    max_s: 0.004,
                },
            )],
        };
        let frag = report.json_fragment();
        assert!(frag.contains("\"p99_ms\": 6.0000"), "{frag}");
        assert!(frag.contains("\"throughput_rps\": 200.00"), "{frag}");
        assert!(frag.contains("\"completed\": 96"), "{frag}");
        assert!(frag.contains("\"shed\": 3"), "{frag}");
        assert!(frag.contains("\"timeouts\": 1"), "{frag}");
        assert!(frag.contains("\"queue_depth_max\": 12"), "{frag}");
        assert!(frag.contains("\"queue_depth_mean\": 4.50"), "{frag}");
        assert!(frag.contains("\"wal_appends\": 8"), "{frag}");
        assert!(frag.contains("\"compactions\": 1"), "{frag}");
        assert!(frag.contains("\"torn_tail\": 0"), "{frag}");
        assert!(frag.contains("\"stages\": {\"align\": {\"count\": 100"), "{frag}");
        assert!(frag.contains("\"p99_ms\": 3.0000"), "{frag}");

        let dir = std::env::temp_dir().join("ivtv_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_2.json");
        write_bench2_json(&p, &[("batched", &report), ("unbatched", &report)]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"schema_version\": 1"));
        assert!(text.contains("\"issue\": 2"));
        assert!(text.contains("\"batched\": {"));
        assert!(text.contains("\"unbatched\": {"));
    }

    /// Streaming acceptance on the shared bundle: every session is
    /// accounted for (decided or typed-rejected, no hard failures),
    /// calibrated early exits fire, and the mean frames consumed per
    /// decision lands below what the one-shot path must ingest for the
    /// exact same trials.
    #[test]
    fn session_streaming_load_decides_on_fewer_frames_than_oneshot() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 33);
        let opts = StreamBenchOpts {
            speakers: 4,
            enroll_utts: 2,
            requests: 24,
            concurrency: 4,
            chunk_frames: 20,
            accept_score: None,
            reject_score: None,
        };
        let (streaming, oneshot, obs) = run_streaming_vs_oneshot(
            shared_test_bundle().clone(),
            &cfg.serve,
            &cfg.obs,
            &traffic,
            &opts,
        )
        .unwrap();

        assert_eq!(streaming.decided + streaming.rejected, opts.requests);
        assert_eq!(streaming.rejected, 0, "a roomy engine must not shed: {streaming:?}");
        assert_eq!(streaming.sessions_opened, opts.requests as u64);
        assert_eq!(streaming.evictions, 0);
        assert_eq!(streaming.shed, 0);
        assert!(streaming.accept_score > streaming.reject_score, "{streaming:?}");
        assert!(streaming.early_exits > 0, "calibrated thresholds must fire: {streaming:?}");
        assert!(
            streaming.mean_frames_per_decision < streaming.mean_frames_available,
            "early exits must save frames: {streaming:?}"
        );
        // the separation the thresholds were calibrated from holds on
        // the streamed (often partial-stat) scores too
        assert!(streaming.target_mean > streaming.impostor_mean, "{streaming:?}");
        assert_eq!(oneshot.requests, opts.requests);

        // the streaming engine's obs registry carries the session
        // stages and validates as a snapshot
        let stages = &streaming.stages;
        let feed = stages.iter().find(|(n, _)| *n == "session_feed").unwrap();
        assert!(feed.1.count >= opts.requests as u64, "one span per chunk fed: {stages:?}");
        let json = obs.render(crate::obs::RenderFormat::Json);
        crate::obs::validate_snapshot(&json).expect("streaming snapshot validates");

        let dir = std::env::temp_dir().join("ivtv_bench8_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_8.json");
        write_bench8_json(&p, &streaming, &oneshot).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("\"issue\": 8"), "{text}");
        assert!(text.contains("\"streaming\": {"), "{text}");
        assert!(text.contains("\"oneshot\": {"), "{text}");
        assert!(text.contains("\"mean_frames_per_decision\""), "{text}");
        assert!(text.contains("\"early_exit_rate\""), "{text}");
    }
}
