//! The serving engine: an immutable, atomically hot-swappable model
//! bundle behind `extract` / `enroll` / `verify`.
//!
//! Request flow (the paper's Fig. 1 pipeline reshaped for serving):
//! the request thread plays the CPU-loader role — alignment + Baum-Welch
//! statistics against its model snapshot — then parks on a response
//! channel while the micro-batcher coalesces concurrent requests into
//! one GEMM-shaped E-step dispatch. Enrollments land in the sharded
//! [`Registry`]; verification scores the averaged enrollment i-vector
//! against the request's i-vector through the bundle's PLDA backend.
//!
//! Every request carries two deadlines derived from the `[serve]`
//! config: the **submit deadline** bounds how long admission control
//! waits for micro-batch queue space (past it the request is shed with
//! a typed [`ServeError::Overloaded`]), and the **request deadline**
//! bounds the wait for the batched response (past it the request fails
//! with [`ServeError::Timeout`] — a stalled worker can no longer hang a
//! request thread forever). Shed/timeout counts and queue-depth stats
//! are part of [`EngineMetrics`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, RecvTimeoutError};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{ServeConfig, SessionConfig};
use crate::ivector::UttStats;
use crate::linalg::Mat;
use crate::metrics::{DepthSummary, LatencyHistogram, LatencySummary};
use crate::obs::{self, Counter, ObsRegistry, Stage, TraceOutcome};

use super::batcher::{MicroBatcher, RequestToken};
use super::bundle::{ModelBundle, ServeModel};
use super::capture::{Recorder, RequestKind};
use super::error::ServeError;
use super::registry::{DurabilityMetrics, Registry};
use super::session::{self, CloseReason, FeedOutcome, SessionManager, SessionState};

/// One verification result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VerifyOutcome {
    /// PLDA log-likelihood ratio (higher = more likely the claimed
    /// speaker; threshold-free, like the offline `eval` scores).
    pub score: f64,
    /// Enrollment utterances behind the claimed speaker's profile.
    pub enrolled_utts: u64,
}

/// Point-in-time engine counters.
#[derive(Debug, Clone, Copy)]
pub struct EngineMetrics {
    pub uptime_s: f64,
    pub extract: LatencySummary,
    pub enroll: LatencySummary,
    pub verify: LatencySummary,
    pub dispatched_batches: u64,
    pub batched_requests: u64,
    /// Requests shed at admission (typed `Overloaded` rejections).
    pub shed_requests: u64,
    /// Requests that missed their response deadline (typed `Timeout`).
    pub timed_out_requests: u64,
    /// Queued jobs purged unprocessed because their caller's deadline
    /// passed first (workers never burn batch slots on dead work).
    pub expired_jobs: u64,
    /// Micro-batch queue occupancy over admitted requests.
    pub queue_depth: DepthSummary,
    /// Jobs queued right now (admitted, not yet dispatched).
    pub queue_len: usize,
    /// Batch workers found dead-by-panic at join — the drop-path drain
    /// used to swallow these, silently shrinking the pool.
    pub worker_panics: u64,
    /// Streaming sessions opened ([`Engine::session_open`]).
    pub sessions_opened: u64,
    /// Sessions finalized early by the score-threshold policy.
    pub session_early_exits: u64,
    /// Sessions reclaimed by the idle-deadline eviction sweep.
    pub session_evictions: u64,
    /// Session opens shed at the table's capacity bound.
    pub session_shed: u64,
    /// Live sessions right now.
    pub live_sessions: usize,
    /// Aligner-scratch pool counters of the *current* model snapshot
    /// (fresh allocations, pooled reuses); reset by a hot swap.
    pub scratch_created: u64,
    pub scratch_reused: u64,
    pub enrolled_speakers: usize,
    /// Registry persistence counters (WAL appends/fsyncs, compactions,
    /// recovery stats); all-zero when the registry is volatile.
    pub durability: DurabilityMetrics,
}

impl EngineMetrics {
    /// Mean requests per dispatched E-step batch.
    pub fn mean_batch(&self) -> f64 {
        if self.dispatched_batches == 0 {
            return 0.0;
        }
        self.batched_requests as f64 / self.dispatched_batches as f64
    }
}

/// The long-lived serving engine. `&Engine` is `Sync`: request threads
/// call `extract`/`enroll`/`verify` concurrently while an operator
/// thread may [`Engine::swap_bundle`] at any time.
pub struct Engine {
    /// The current model, swapped atomically; requests snapshot the
    /// `Arc` once and stay on that snapshot end-to-end.
    model: RwLock<Arc<ServeModel>>,
    /// Shared so cluster replicas can serve one speaker store
    /// ([`Engine::with_registry`]); a standalone engine owns the only
    /// handle.
    registry: Arc<Registry>,
    batcher: MicroBatcher,
    /// Set by [`Engine::drain`]: the request path fast-fails with a
    /// typed [`ServeError::ShuttingDown`] before doing alignment work.
    draining: AtomicBool,
    /// Admission bound: max wait for queue space before shedding.
    submit_timeout: Duration,
    /// End-to-end bound: max wait for the batched response.
    request_timeout: Duration,
    /// Scratch-pool bound handed to each `ServeModel` (hot swaps too).
    scratch_pool: usize,
    /// Alignment scoring precision handed to each `ServeModel`
    /// (`[align] precision`; hot swaps inherit it).
    precision: crate::gmm::AlignPrecision,
    /// The observability registry every engine instrument lives in —
    /// shared with the micro-batcher, and with sibling replicas when a
    /// cluster dispatcher owns the engines.
    obs: Arc<ObsRegistry>,
    /// This engine's `engine="<label>"` instrument label; Drop
    /// deregisters the labeled series so a swapped-out replica stops
    /// appearing in exports.
    obs_label: String,
    /// Streaming-session table (admission, idle eviction, counters);
    /// the session *ops* live on the engine because they need the
    /// registry, the batcher, and the obs spans.
    sessions: SessionManager,
    /// Early-exit policy + table shape (`[session]`).
    session_cfg: SessionConfig,
    /// Optional flight recorder: when set, every one-shot request this
    /// engine handles *directly* (not via a cluster dispatcher — those
    /// are captured once, at the dispatcher) is offered to the capture
    /// log after completion, off the request's critical path.
    recorder: RwLock<Option<Arc<Recorder>>>,
    /// Requests that missed their response deadline
    /// (`serve_timeouts_total`).
    timeouts: Counter,
    extract_lat: Arc<LatencyHistogram>,
    enroll_lat: Arc<LatencyHistogram>,
    verify_lat: Arc<LatencyHistogram>,
    started: Instant,
}

impl Engine {
    /// Spin up the worker pool around a bundle. Rejects a bundle whose
    /// backend dims disagree with its extractor — the in-process
    /// counterpart of the `load_auto` check, so a mixed-artifact bundle
    /// fails here instead of panicking inside `project` on the first
    /// verify.
    pub fn new(bundle: ModelBundle, opts: &ServeConfig) -> Result<Self> {
        Self::with_registry(bundle, opts, Arc::new(Registry::new(opts.registry_shards)))
    }

    /// [`Engine::new`] with an externally-owned speaker registry — the
    /// cluster constructor: N replica engines share one `Arc<Registry>`
    /// so an enrollment on any replica is visible to every replica (and
    /// survives a per-replica drain/rebuild during a rolling swap).
    pub fn with_registry(
        bundle: ModelBundle,
        opts: &ServeConfig,
        registry: Arc<Registry>,
    ) -> Result<Self> {
        Self::with_registry_obs(bundle, opts, registry, Arc::new(ObsRegistry::default()))
    }

    /// [`Engine::with_registry`] with an externally-owned observability
    /// registry — the cluster dispatcher passes one shared registry to
    /// every replica so the whole fleet exports through a single
    /// snapshot; a standalone engine gets a private default.
    pub fn with_registry_obs(
        bundle: ModelBundle,
        opts: &ServeConfig,
        registry: Arc<Registry>,
        obs: Arc<ObsRegistry>,
    ) -> Result<Self> {
        bundle.check_backend_dims()?;
        let obs_label = obs.next_instance().to_string();
        let labels = [("engine", obs_label.as_str())];
        Ok(Self {
            model: RwLock::new(Arc::new(ServeModel::with_options(
                bundle,
                opts.scratch_pool,
                opts.precision,
            ))),
            registry,
            batcher: MicroBatcher::new(
                opts.batch_utts,
                Duration::from_micros(opts.flush_us),
                opts.workers,
                opts.queue_cap,
                Arc::clone(&obs),
                &obs_label,
            ),
            draining: AtomicBool::new(false),
            submit_timeout: Duration::from_millis(opts.submit_timeout_ms.max(1)),
            request_timeout: Duration::from_millis(opts.request_timeout_ms.max(1)),
            scratch_pool: opts.scratch_pool,
            precision: opts.precision,
            sessions: SessionManager::new(&opts.session, &obs, &obs_label),
            session_cfg: opts.session.clone(),
            recorder: RwLock::new(None),
            timeouts: obs.counter("serve_timeouts_total", &labels),
            extract_lat: obs.histogram("serve_extract_latency_seconds", &labels),
            enroll_lat: obs.histogram("serve_enroll_latency_seconds", &labels),
            verify_lat: obs.histogram("serve_verify_latency_seconds", &labels),
            obs,
            obs_label,
            started: Instant::now(),
        })
    }

    /// The observability registry this engine reports into.
    pub fn obs(&self) -> &Arc<ObsRegistry> {
        &self.obs
    }

    /// Attach (or detach, with `None`) a flight recorder. Captures
    /// happen after a request completes and go through a bounded
    /// channel, so a slow capture sink can drop records but can never
    /// block or slow the request thread.
    pub fn set_recorder(&self, rec: Option<Arc<Recorder>>) {
        *self.recorder.write().unwrap() = rec;
    }

    /// Snapshot the current model.
    pub fn model(&self) -> Arc<ServeModel> {
        self.model.read().unwrap().clone()
    }

    /// Atomically replace the model bundle. In-flight requests finish
    /// on the snapshot they started with; the micro-batcher never mixes
    /// snapshots within a batch. A backend/extractor dim mismatch is
    /// rejected here (the current model stays installed) — hot swaps
    /// must not be able to arm a panic for the next verify request.
    pub fn swap_bundle(&self, bundle: ModelBundle) -> Result<()> {
        bundle.check_backend_dims()?;
        let next =
            Arc::new(ServeModel::with_options(bundle, self.scratch_pool, self.precision));
        *self.model.write().unwrap() = next;
        Ok(())
    }

    /// The speaker registry (persistence, admin).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// A shared handle to the registry — what a cluster dispatcher
    /// passes to the next replica ([`Engine::with_registry`]).
    pub fn registry_handle(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// Jobs currently admitted but not yet dispatched — the live load
    /// signal a least-depth router combines with its in-flight counter
    /// (the historical max/mean live in `EngineMetrics.queue_depth`).
    pub fn queue_len(&self) -> usize {
        self.batcher.queue_len()
    }

    /// Drain the engine: stop admitting (new submits fail with a typed
    /// [`ServeError::ShuttingDown`]), let workers finish everything
    /// already queued, and join them — bounded by `timeout`. Returns
    /// true once every worker has been joined (false = some worker was
    /// still mid-batch at the deadline; drop joins the stragglers).
    /// Idempotent: a second drain returns immediately.
    pub fn drain(&self, timeout: Duration) -> bool {
        self.draining.store(true, Ordering::Release);
        self.batcher.shutdown();
        self.batcher.join_workers(Some(Instant::now() + timeout))
    }

    /// True once [`Engine::drain`] has begun: the engine rejects new
    /// requests and its workers are exiting (or gone).
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Deliberately freeze (or thaw) this engine's worker pool — the
    /// deterministic stand-in for a degraded replica that the failover
    /// tests and `cluster-bench --stall-replica` use. Crate-only:
    /// outside code must never be able to stall a serving engine.
    pub(crate) fn stall_workers(&self, stalled: bool) {
        self.batcher.set_stalled(stalled);
    }

    /// Fault hook: panic this engine's next `n` batch dispatches (the
    /// chaos drill's deterministic worker-failure injection — each one
    /// surfaces to the waiting callers as [`ServeError::WorkerFailed`]).
    pub(crate) fn panic_next_batches(&self, n: u64) {
        self.batcher.panic_next_batches(n);
    }

    /// Extraction against an explicit snapshot — the shared inner path.
    /// Deadline-bounded end to end: admission sheds past the submit
    /// deadline, and a stalled worker surfaces as a typed timeout
    /// instead of hanging this thread.
    fn extract_with(&self, model: &Arc<ServeModel>, feats: &Mat) -> Result<Vec<f64>> {
        // a draining engine sheds before the alignment work, not after:
        // the caller (or the dispatcher above it) retries elsewhere, so
        // burning the loader stage here would be pure waste
        if self.draining.load(Ordering::Acquire) {
            return Err(ServeError::ShuttingDown.into());
        }
        let t0 = Instant::now();
        // announce before the loader work so batch workers know a
        // co-rider is on the way and hold sub-size batches for it
        let token = self.batcher.begin_request();
        let align_span = self.obs.span(Stage::Align);
        let stats = model.utt_stats(feats);
        align_span.finish();
        self.submit_stats(model, stats, t0, token)
    }

    /// Submit precomputed Baum-Welch statistics into the micro-batcher
    /// and await the batched i-vector — the lower half of
    /// [`Engine::extract_with`], shared with the session ops: a
    /// session's partial-stat jobs ride the same model-coherent batches
    /// as one-shot requests (its pinned snapshot simply splits the
    /// batch at the epoch boundary after a swap). `t0` anchors the
    /// request deadline; `token` is the co-rider announcement made
    /// before the caller's loader work.
    fn submit_stats(
        &self,
        model: &Arc<ServeModel>,
        stats: UttStats,
        t0: Instant,
        token: RequestToken<'_>,
    ) -> Result<Vec<f64>> {
        let request_deadline = t0 + self.request_timeout;
        // the admission budget starts *after* the loader work:
        // submit_timeout bounds the wait for queue space, so a long
        // utterance's alignment must not eat the budget and turn every
        // transiently-full queue into an instant shed
        let submit_deadline = (Instant::now() + self.submit_timeout).min(request_deadline);
        let (tx, rx) = sync_channel(1);
        let admit_span = self.obs.span(Stage::AdmitWait);
        let admitted =
            self.batcher.submit(stats, Arc::clone(model), tx, submit_deadline, request_deadline);
        admit_span.finish();
        admitted?;
        drop(token); // queued: no longer "on the way"
        let remaining = request_deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(remaining) {
            Ok(ivector) => Ok(ivector),
            Err(RecvTimeoutError::Timeout) => {
                self.timeouts.inc();
                Err(ServeError::Timeout { waited: t0.elapsed() }.into())
            }
            Err(RecvTimeoutError::Disconnected) => {
                // a purged (expired) job also surfaces as Disconnected —
                // classify by the deadline, so overload is reported as a
                // timeout and never masquerades as a broken worker
                if Instant::now() >= request_deadline {
                    self.timeouts.inc();
                    Err(ServeError::Timeout { waited: t0.elapsed() }.into())
                } else {
                    Err(ServeError::WorkerFailed.into())
                }
            }
        }
    }

    /// Run a request closure under a freshly-minted trace, unless the
    /// caller (a cluster dispatcher) already installed one on this
    /// thread — then the request joins the existing trace so failover
    /// hops accumulate into a single record.
    fn traced<T>(&self, f: impl FnOnce() -> Result<T>) -> Result<T> {
        if obs::current().is_some() {
            return f();
        }
        let Some(trace) = self.obs.mint() else {
            return f();
        };
        let scope = obs::enter(Arc::clone(&trace));
        let r = f();
        drop(scope);
        self.obs.complete(&trace, TraceOutcome::of(&r));
        r
    }

    /// [`Engine::traced`] plus an offer to the attached flight
    /// recorder (if any). Dispatcher-driven requests (a trace already
    /// installed on this thread) skip capture here — the dispatcher
    /// records them once, with the full cross-replica span set.
    /// Capture still works with tracing disabled: the record simply
    /// carries no per-stage spans.
    fn traced_cap<T>(
        &self,
        kind: RequestKind,
        speaker: &str,
        feats: &Mat,
        score_of: impl Fn(&T) -> Option<f64>,
        f: impl FnOnce() -> Result<T>,
    ) -> Result<T> {
        if obs::current().is_some() {
            return f();
        }
        let rec = self.recorder.read().unwrap().clone();
        let Some(trace) = self.obs.mint() else {
            let Some(rec) = rec else { return f() };
            let t0 = Instant::now();
            let r = f();
            let score = r.as_ref().ok().and_then(&score_of);
            rec.observe(kind, speaker, feats, TraceOutcome::of(&r), score, t0.elapsed(), None);
            return r;
        };
        let t0 = Instant::now();
        let scope = obs::enter(Arc::clone(&trace));
        let r = f();
        drop(scope);
        self.obs.complete(&trace, TraceOutcome::of(&r));
        if let Some(rec) = rec {
            let score = r.as_ref().ok().and_then(&score_of);
            rec.observe(
                kind,
                speaker,
                feats,
                TraceOutcome::of(&r),
                score,
                t0.elapsed(),
                Some(&trace),
            );
        }
        r
    }

    /// Extract one i-vector for a feature matrix (frames × dim).
    pub fn extract(&self, feats: &Mat) -> Result<Vec<f64>> {
        self.traced_cap(RequestKind::Extract, "", feats, |_| None, || {
            let t0 = Instant::now();
            let model = self.model();
            let iv = self.extract_with(&model, feats)?;
            self.extract_lat.record(t0.elapsed().as_secs_f64());
            Ok(iv)
        })
    }

    /// Enroll one utterance for a speaker (averaged with any previous
    /// enrollments); returns the speaker's new utterance count. The
    /// profile is tagged with the model fingerprint, so enrollments
    /// never mix models across a hot swap.
    pub fn enroll(&self, speaker_id: &str, feats: &Mat) -> Result<u64> {
        self.traced_cap(
            RequestKind::Enroll,
            speaker_id,
            feats,
            |count| Some(*count as f64),
            || {
                let t0 = Instant::now();
                let model = self.model();
                let iv = self.extract_with(&model, feats)?;
                let count = self.registry.enroll(speaker_id, &iv, model.fingerprint)?;
                self.enroll_lat.record(t0.elapsed().as_secs_f64());
                Ok(count)
            },
        )
    }

    /// Verify an utterance against an enrolled speaker. Refuses to
    /// score a profile enrolled under a different model than the
    /// current bundle — i-vectors from different total-variability
    /// spaces are not comparable, so the mismatch is an error rather
    /// than a plausible-looking meaningless score.
    pub fn verify(&self, speaker_id: &str, feats: &Mat) -> Result<VerifyOutcome> {
        self.traced_cap(RequestKind::Verify, speaker_id, feats, |out| Some(out.score), || {
            let t0 = Instant::now();
            let model = self.model();
            let profile = self
                .registry
                .profile(speaker_id)
                .ok_or_else(|| anyhow!("speaker `{speaker_id}` is not enrolled"))?;
            anyhow::ensure!(
                profile.model_fp == model.fingerprint,
                "speaker `{speaker_id}` was enrolled under a different model — \
                 re-enroll after the bundle swap"
            );
            let iv = self.extract_with(&model, feats)?;
            let project_span = self.obs.span(Stage::BackendProject);
            let score = model.score(&profile.mean(), &iv);
            project_span.finish();
            self.verify_lat.record(t0.elapsed().as_secs_f64());
            Ok(VerifyOutcome { score, enrolled_utts: profile.count })
        })
    }

    /// Open a streaming session for an enrolled speaker, pinning the
    /// current model snapshot: every later feed aligns and every score
    /// finalizes against that snapshot, so a hot swap mid-session can
    /// never mix total-variability spaces. Sheds typed
    /// ([`ServeError::SessionLimit`]) at the table's capacity bound.
    pub fn session_open(&self, speaker_id: &str) -> Result<u64> {
        self.traced(|| {
            if self.draining.load(Ordering::Acquire) {
                return Err(ServeError::ShuttingDown.into());
            }
            // opportunistic eviction on the open path keeps the table
            // honest without a background thread (a pointer walk over
            // ≤ max_sessions entries)
            self.sessions.sweep();
            let model = self.model();
            let profile = self
                .registry
                .profile(speaker_id)
                .ok_or_else(|| anyhow!("speaker `{speaker_id}` is not enrolled"))?;
            anyhow::ensure!(
                profile.model_fp == model.fingerprint,
                "speaker `{speaker_id}` was enrolled under a different model — \
                 re-enroll after the bundle swap"
            );
            self.sessions.open(speaker_id.to_string(), model)
        })
    }

    /// Feed one chunk of frames into a session: chunk alignment + stat
    /// absorption on the caller's thread (the streaming loader stage).
    /// With an early-exit threshold configured and `min_frames`
    /// reached, the interim partial-stat score is taken through the
    /// batcher and may finalize the session right here
    /// ([`FeedOutcome::Decided`]).
    pub fn session_feed(&self, id: u64, chunk: &Mat) -> Result<FeedOutcome> {
        self.traced(|| {
            if self.draining.load(Ordering::Acquire) {
                return Err(ServeError::ShuttingDown.into());
            }
            let sess = self.checkout_session(id)?;
            let mut st = sess.lock().unwrap();
            let feed_span = self.obs.span(Stage::SessionFeed);
            {
                let SessionState { model, accum, .. } = &mut *st;
                model.absorb(accum, chunk);
            }
            feed_span.finish();
            st.last_active = Instant::now();
            let frames = st.frames();
            let p = &self.session_cfg;
            if (p.accept_score.is_some() || p.reject_score.is_some()) && frames >= p.min_frames
            {
                let (score, _) = self.score_session_state(&mut st)?;
                if let Some(accepted) = session::early_exit_decision(p, frames, score) {
                    drop(st);
                    self.sessions.close(id, CloseReason::EarlyExit);
                    return Ok(FeedOutcome::Decided { score, frames, accepted });
                }
            }
            Ok(FeedOutcome::Pending { frames })
        })
    }

    /// Score a session's accumulated stats *now*, without closing it —
    /// the caller can keep feeding and score again. Exact for the
    /// frames absorbed so far (a mid-stream finalize equals the
    /// one-shot score of the same prefix).
    pub fn session_score(&self, id: u64) -> Result<VerifyOutcome> {
        self.traced(|| {
            if self.draining.load(Ordering::Acquire) {
                return Err(ServeError::ShuttingDown.into());
            }
            let sess = self.checkout_session(id)?;
            let mut st = sess.lock().unwrap();
            let (score, enrolled_utts) = self.score_session_state(&mut st)?;
            Ok(VerifyOutcome { score, enrolled_utts })
        })
    }

    /// Final score + close: the utterance ended without an early exit.
    /// Later ops on the id fail typed ([`ServeError::SessionClosed`]).
    pub fn session_close(&self, id: u64) -> Result<VerifyOutcome> {
        self.traced(|| {
            if self.draining.load(Ordering::Acquire) {
                return Err(ServeError::ShuttingDown.into());
            }
            let sess = self.checkout_session(id)?;
            let mut st = sess.lock().unwrap();
            let (score, enrolled_utts) = self.score_session_state(&mut st)?;
            drop(st);
            self.sessions.close(id, CloseReason::Done);
            Ok(VerifyOutcome { score, enrolled_utts })
        })
    }

    /// The session table (sweep control, live count, counters).
    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }

    /// Look up a live session, applying the idle deadline lazily: an
    /// expired-but-unswept session is reclaimed here instead of served.
    fn checkout_session(&self, id: u64) -> Result<Arc<std::sync::Mutex<SessionState>>> {
        let sess = self.sessions.lookup(id)?;
        let expired = {
            let st = sess.lock().unwrap();
            st.last_active.elapsed() >= self.sessions.idle_deadline()
        };
        if expired {
            self.sessions.close(id, CloseReason::Expired);
            return Err(ServeError::SessionExpired.into());
        }
        Ok(sess)
    }

    /// Score a session's partial stats against its claimed speaker's
    /// profile on the *pinned* model — shared by `session_score`,
    /// `session_close`, and early-exit feeds. The caller holds the
    /// session lock, so concurrent feeds to the same session serialize
    /// behind the score.
    fn score_session_state(&self, st: &mut SessionState) -> Result<(f64, u64)> {
        let profile = self
            .registry
            .profile(&st.speaker)
            .ok_or_else(|| anyhow!("speaker `{}` is no longer enrolled", st.speaker))?;
        // the profile must belong to the *session's* space, not the
        // engine's current one: a swap leaves the session scorable as
        // long as the profile still carries the pinned fingerprint
        anyhow::ensure!(
            profile.model_fp == st.model.fingerprint,
            "speaker `{}` was re-enrolled under a different model than this session \
             pinned at open — close the session and reopen",
            st.speaker
        );
        let t0 = Instant::now();
        let token = self.batcher.begin_request();
        let score_span = self.obs.span(Stage::SessionScore);
        let stats = st.model.finalize_accum(&st.accum);
        score_span.finish();
        let model = Arc::clone(&st.model);
        let iv = self.submit_stats(&model, stats, t0, token)?;
        let project_span = self.obs.span(Stage::BackendProject);
        let score = model.score(&profile.mean(), &iv);
        project_span.finish();
        st.last_active = Instant::now();
        Ok((score, profile.count))
    }

    /// Counters snapshot.
    pub fn metrics(&self) -> EngineMetrics {
        let (scratch_created, scratch_reused) = self.model().scratch_stats();
        EngineMetrics {
            uptime_s: self.started.elapsed().as_secs_f64(),
            extract: self.extract_lat.summary(),
            enroll: self.enroll_lat.summary(),
            verify: self.verify_lat.summary(),
            dispatched_batches: self.batcher.dispatched_batches(),
            batched_requests: self.batcher.batched_requests(),
            shed_requests: self.batcher.shed_requests(),
            timed_out_requests: self.timeouts.get(),
            expired_jobs: self.batcher.expired_jobs(),
            worker_panics: self.batcher.worker_panics(),
            sessions_opened: self.sessions.opened(),
            session_early_exits: self.sessions.early_exits(),
            session_evictions: self.sessions.evictions(),
            session_shed: self.sessions.shed_opens(),
            live_sessions: self.sessions.live(),
            queue_depth: self.batcher.queue_depth(),
            queue_len: self.batcher.queue_len(),
            scratch_created,
            scratch_reused,
            enrolled_speakers: self.registry.len(),
            durability: self.registry.durability_metrics(),
        }
    }
}

impl Drop for Engine {
    /// Tests and short-lived CLI commands must not leak worker threads:
    /// dropping the engine drains it (typed `ShuttingDown` for any
    /// racing submitter, workers finish the queue and are joined). The
    /// bound only caps the *polling* join here — `MicroBatcher`'s own
    /// drop joins any straggler unconditionally right after.
    fn drop(&mut self) {
        self.drain(Duration::from_secs(5));
        // retire this instance's labeled series: a rolling swap must not
        // leak one generation of engine instruments per swap into every
        // future export (the counters themselves stay alive through the
        // handles any in-flight reader still holds)
        self.obs.remove_label("engine", &self.obs_label);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;

    use super::super::bench::{shared_test_bundle as shared_bundle, tiny_serve_config, tiny_traffic};
    use super::*;
    use crate::ivector::extract_cpu;

    fn opts(batch_utts: usize, flush_us: u64, workers: usize) -> ServeConfig {
        ServeConfig {
            batch_utts,
            flush_us,
            workers,
            registry_shards: 4,
            queue_cap: 256,
            // generous request-path deadlines: the functional tests
            // exercise correctness, not admission control
            submit_timeout_ms: 10_000,
            request_timeout_ms: 60_000,
            scratch_pool: 4,
            precision: crate::gmm::AlignPrecision::F64,
            session: SessionConfig::default(),
        }
    }

    /// Copy `utt` rows `[lo, hi)` into a fresh chunk matrix.
    fn chunk_of(utt: &Mat, lo: usize, hi: usize) -> Mat {
        Mat::from_fn(hi - lo, utt.cols(), |t, j| utt.get(lo + t, j))
    }

    #[test]
    fn prop_serve_extraction_matches_extract_cpu() {
        // acceptance: batched serve-path extraction ≡ extract_cpu on the
        // same features (≤ 1e-10)
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 77);
        let engine = Engine::new(shared_bundle().clone(), &opts(4, 300, 2)).unwrap();
        let model = engine.model();
        crate::proptest::forall(
            20_2507,
            16,
            |rng| {
                let s = rng.below(4);
                let k = rng.below(64) as u64;
                (s, k)
            },
            |&(s, k)| {
                let feats = traffic.utterance(s, k);
                let got = engine.extract(&feats).map_err(|e| e.to_string())?;
                let stats = model.utt_stats(&feats);
                let want = extract_cpu(&model.bundle.tvm, std::slice::from_ref(&stats), 1);
                for (j, (g, w)) in got.iter().zip(want.row(0)).enumerate() {
                    if (g - w).abs() > 1e-10 * (1.0 + w.abs()) {
                        return Err(format!("coord {j}: {g} vs {w}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_requests_coalesce_into_batches() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 13);
        // one worker + generous deadline: near-simultaneous requests
        // must ride shared dispatches
        let engine = Engine::new(shared_bundle().clone(), &opts(8, 200_000, 1)).unwrap();
        let n = 16;
        let barrier = std::sync::Barrier::new(n);
        std::thread::scope(|scope| {
            for i in 0..n {
                let engine = &engine;
                let traffic = &traffic;
                let barrier = &barrier;
                scope.spawn(move || {
                    let feats = traffic.utterance(i % 4, i as u64);
                    barrier.wait();
                    engine.extract(&feats).unwrap();
                });
            }
        });
        let m = engine.metrics();
        assert_eq!(m.batched_requests, 16);
        assert!(m.dispatched_batches >= 2, "batches {}", m.dispatched_batches);
        // inbound-aware early flush makes exact batch counts scheduling
        // dependent; requiring strictly fewer batches than requests
        // still proves coalescing happened
        assert!(
            m.dispatched_batches < 16,
            "16 near-simultaneous requests should coalesce, got {} batches",
            m.dispatched_batches
        );
        assert_eq!(m.extract.count, 16);
    }

    /// Tentpole acceptance (serving side): `[align] precision = "f32"`
    /// reaches the request path — the engine's extraction equals the
    /// f32 serial oracle bit-for-bit (identical alignment + f64 E-step)
    /// and a hot swap inherits the precision.
    #[test]
    fn f32_engine_matches_f32_oracle_and_survives_swap() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 23);
        let mut o = opts(4, 300, 2);
        o.precision = crate::gmm::AlignPrecision::F32;
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();
        let model = engine.model();
        assert_eq!(model.precision(), crate::gmm::AlignPrecision::F32);
        for s in 0..2 {
            let feats = traffic.utterance(s, 5);
            let got = engine.extract(&feats).unwrap();
            let want = model.extract_serial(&feats);
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((g - w).abs() <= 1e-10 * (1.0 + w.abs()), "coord {j}: {g} vs {w}");
            }
        }
        // a hot swap keeps serving at the configured precision
        engine.swap_bundle(shared_bundle().clone()).unwrap();
        assert_eq!(engine.model().precision(), crate::gmm::AlignPrecision::F32);
        engine.extract(&traffic.utterance(0, 9)).unwrap();
    }

    #[test]
    fn verify_after_incompatible_swap_is_rejected() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 17);
        let bundle = shared_bundle().clone();
        let engine = Engine::new(bundle.clone(), &opts(2, 300, 1)).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        // a value-identical swap keeps the profile scorable
        engine.swap_bundle(bundle.clone()).unwrap();
        engine.verify(&id, &traffic.utterance(0, 1)).unwrap();
        // a retrained-model stand-in: same dims, different parameters
        let mut other = bundle;
        *other.tvm.t[0].get_mut(0, 0) += 0.5;
        engine.swap_bundle(other).unwrap();
        let err = engine.verify(&id, &traffic.utterance(0, 1)).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // mixing epochs within one profile is refused too
        let err = engine.enroll(&id, &traffic.utterance(0, 2)).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // removing the stale profile unblocks enrollment under the new model
        assert!(engine.registry().remove(&id).unwrap());
        engine.enroll(&id, &traffic.utterance(0, 2)).unwrap();
        engine.verify(&id, &traffic.utterance(0, 3)).unwrap();
    }

    #[test]
    fn unknown_speaker_is_rejected() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 3);
        let engine = Engine::new(shared_bundle().clone(), &opts(2, 200, 1)).unwrap();
        let err = engine.verify("nobody", &traffic.utterance(0, 0)).unwrap_err();
        assert!(err.to_string().contains("not enrolled"), "{err}");
    }

    #[test]
    fn verify_scores_separate_target_from_impostor() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 21);
        let engine = Engine::new(shared_bundle().clone(), &opts(4, 500, 2)).unwrap();
        let id = traffic.speaker_id(0);
        for k in 0..3 {
            engine.enroll(&id, &traffic.utterance(0, k)).unwrap();
        }
        // mean over several trials — a single pair at tiny dims is noisy
        let mut target = 0.0;
        let mut impostor = 0.0;
        for k in 50..56 {
            let t = engine.verify(&id, &traffic.utterance(0, k)).unwrap();
            assert_eq!(t.enrolled_utts, 3);
            target += t.score;
            impostor += engine.verify(&id, &traffic.utterance(1, k)).unwrap().score;
        }
        assert!(
            target > impostor,
            "mean target {} must out-score mean impostor {}",
            target / 6.0,
            impostor / 6.0
        );
    }

    /// Satellite acceptance: N threads enroll/verify against one engine
    /// while hot swaps replace the bundle mid-flight — no lost
    /// enrollments, scores identical to the single-threaded oracle.
    #[test]
    fn concurrent_enroll_verify_with_hot_swap_matches_oracle() {
        let cfg = tiny_serve_config();
        let bundle = shared_bundle().clone();
        let oracle = ServeModel::new(bundle.clone());
        // speakers 0..8 owned by the worker threads; 8 is the shared
        // contended speaker every thread also enrolls
        let traffic = tiny_traffic(&cfg, 9, 99);
        let engine = Engine::new(bundle.clone(), &opts(4, 1_000, 2)).unwrap();
        let n_threads = 4usize;
        let enroll_utts = 2usize;
        let running = AtomicBool::new(true);
        let scores: Mutex<Vec<(usize, f64, f64)>> = Mutex::new(Vec::new());

        std::thread::scope(|scope| {
            // hot-swapper: replaces the bundle (with identical values)
            // while requests are in flight
            let swapper = {
                let engine = &engine;
                let bundle = &bundle;
                let running = &running;
                scope.spawn(move || {
                    while running.load(Ordering::Relaxed) {
                        engine.swap_bundle(bundle.clone()).unwrap();
                        std::thread::sleep(Duration::from_millis(1));
                    }
                })
            };
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let engine = &engine;
                    let traffic = &traffic;
                    let scores = &scores;
                    scope.spawn(move || {
                        for rep in 0..2 {
                            let spk = t * 2 + rep;
                            let id = traffic.speaker_id(spk);
                            for k in 0..enroll_utts {
                                engine.enroll(&id, &traffic.utterance(spk, k as u64)).unwrap();
                            }
                            // contended speaker: identical utterance from
                            // every thread, so the running sum is exact
                            // in any interleaving
                            engine.enroll("shared", &traffic.utterance(8, 0)).unwrap();
                            let target =
                                engine.verify(&id, &traffic.utterance(spk, 100)).unwrap();
                            let impostor = engine
                                .verify(&id, &traffic.utterance((spk + 1) % 8, 100))
                                .unwrap();
                            scores.lock().unwrap().push((spk, target.score, impostor.score));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            running.store(false, Ordering::Relaxed);
            swapper.join().unwrap();
        });

        // no lost enrollments under contention
        let reg = engine.registry();
        assert_eq!(reg.len(), 9, "8 per-thread speakers + the shared one");
        assert_eq!(
            reg.profile("shared").unwrap().count,
            (n_threads * 2) as u64,
            "every thread's shared enrollments must land"
        );
        assert_eq!(reg.total_enrollments(), (8 * enroll_utts + n_threads * 2) as u64);
        for spk in 0..8 {
            assert_eq!(
                reg.profile(&traffic.speaker_id(spk)).unwrap().count,
                enroll_utts as u64
            );
        }

        // scores identical to the single-threaded oracle
        let results = scores.into_inner().unwrap();
        assert_eq!(results.len(), 8);
        for (spk, target, impostor) in results {
            let mut sum = vec![0.0; oracle.rank()];
            for k in 0..enroll_utts {
                let iv = oracle.extract_serial(&traffic.utterance(spk, k as u64));
                for (s, x) in sum.iter_mut().zip(&iv) {
                    *s += x;
                }
            }
            let mean: Vec<f64> = sum.iter().map(|&x| x / enroll_utts as f64).collect();
            let want_t =
                oracle.score(&mean, &oracle.extract_serial(&traffic.utterance(spk, 100)));
            let want_i = oracle.score(
                &mean,
                &oracle.extract_serial(&traffic.utterance((spk + 1) % 8, 100)),
            );
            assert!(
                (target - want_t).abs() <= 1e-12 * (1.0 + want_t.abs()),
                "spk {spk}: target {target} vs oracle {want_t}"
            );
            assert!(
                (impostor - want_i).abs() <= 1e-12 * (1.0 + want_i.abs()),
                "spk {spk}: impostor {impostor} vs oracle {want_i}"
            );
        }
    }

    /// Wait (bounded) until the batcher holds exactly `n` queued jobs.
    fn await_queue_depth(engine: &Engine, n: usize) {
        let t0 = Instant::now();
        while engine.batcher.queue_len() != n {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "queue never reached depth {n} (at {})",
                engine.batcher.queue_len()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Tentpole acceptance: with the queue at `queue_cap` and more
    /// submitters than workers, the excess request is shed with the
    /// typed overload error within its submit deadline, while every
    /// admitted request still matches the `extract_cpu` oracle.
    #[test]
    fn saturated_queue_sheds_within_submit_deadline() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 55);
        let mut o = opts(2, 500, 1);
        o.queue_cap = 2;
        o.submit_timeout_ms = 120;
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();
        let model = engine.model();

        // deterministic saturation: freeze the worker pool, fill the
        // queue to queue_cap with requests that will complete later
        engine.batcher.set_stalled(true);
        std::thread::scope(|scope| {
            let fillers: Vec<_> = (0..2)
                .map(|i| {
                    let engine = &engine;
                    let traffic = &traffic;
                    scope.spawn(move || {
                        let feats = traffic.utterance(i % 2, i as u64);
                        (i, engine.extract(&feats).unwrap())
                    })
                })
                .collect();
            await_queue_depth(&engine, 2);

            // the queue is at capacity and nobody drains it: this
            // submitter must be load-shed, typed and on time
            let t0 = Instant::now();
            let err = engine.extract(&traffic.utterance(0, 99)).unwrap_err();
            let waited = t0.elapsed();
            let typed = err.downcast_ref::<ServeError>().expect("typed serve error");
            assert!(
                matches!(typed, ServeError::Overloaded { .. }),
                "expected Overloaded, got {typed:?}"
            );
            assert!(typed.is_rejection());
            assert!(
                waited >= Duration::from_millis(100),
                "shed before the deadline: {waited:?}"
            );
            assert!(
                waited < Duration::from_secs(5),
                "shed long after the deadline: {waited:?}"
            );

            // thaw: the admitted requests complete, bit-correct
            engine.batcher.set_stalled(false);
            for f in fillers {
                let (i, got) = f.join().unwrap();
                let want = model.extract_serial(&traffic.utterance(i % 2, i as u64));
                for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-10 * (1.0 + w.abs()),
                        "filler {i} coord {j}: {g} vs {w}"
                    );
                }
            }
        });

        let m = engine.metrics();
        assert_eq!(m.shed_requests, 1);
        assert_eq!(m.timed_out_requests, 0);
        assert_eq!(m.queue_depth.max, 2, "queue must have reached queue_cap");
        assert_eq!(m.queue_len, 0, "queue drained after thaw");
        // the engine keeps serving after the shed
        engine.extract(&traffic.utterance(1, 7)).unwrap();
    }

    /// A stalled worker cannot hang a request thread: the response wait
    /// is bounded by the request deadline and surfaces as a typed
    /// timeout.
    #[test]
    fn stalled_worker_times_out_the_request_not_the_thread() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 66);
        let mut o = opts(2, 300, 1);
        o.request_timeout_ms = 150;
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();

        engine.batcher.set_stalled(true);
        let t0 = Instant::now();
        let err = engine.extract(&traffic.utterance(0, 0)).unwrap_err();
        let waited = t0.elapsed();
        let typed = err.downcast_ref::<ServeError>().expect("typed serve error");
        assert!(matches!(typed, ServeError::Timeout { .. }), "expected Timeout, got {typed:?}");
        assert!(waited >= Duration::from_millis(140), "gave up early: {waited:?}");
        assert!(waited < Duration::from_secs(5), "hung past the deadline: {waited:?}");
        assert_eq!(engine.metrics().timed_out_requests, 1);

        // thaw: the expired job is purged unprocessed (its caller is
        // gone) and fresh requests serve normally
        engine.batcher.set_stalled(false);
        engine.extract(&traffic.utterance(0, 1)).unwrap();
        let m = engine.metrics();
        assert_eq!(m.expired_jobs, 1, "the timed-out job must be purged, not dispatched");
        assert_eq!(m.batched_requests, 1, "only the fresh request may reach the E-step");
    }

    /// A dim-mismatched backend is rejected at construction and at hot
    /// swap — never armed to panic inside `project` on the next verify.
    #[test]
    fn mismatched_backend_is_rejected_at_new_and_swap() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 44);
        let mut bad = shared_bundle().clone();
        bad.backend.centering.mean.push(0.0); // backend now expects rank+1
        let err = Engine::new(bad.clone(), &opts(2, 300, 1)).unwrap_err();
        assert!(err.to_string().contains("different extractor"), "{err}");

        let engine = Engine::new(shared_bundle().clone(), &opts(2, 300, 1)).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        let err = engine.swap_bundle(bad).unwrap_err();
        assert!(err.to_string().contains("different extractor"), "{err}");
        // the rejected swap left the current model installed and serving
        engine.verify(&id, &traffic.utterance(0, 1)).unwrap();
    }

    /// Satellite acceptance: a poisoned (NaN-stats) batch errors its own
    /// requests through the `catch_unwind` path while the same worker
    /// keeps serving subsequent requests correctly.
    #[test]
    fn poisoned_batch_errors_without_killing_the_worker() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 88);
        let engine = Engine::new(shared_bundle().clone(), &opts(2, 300, 1)).unwrap();
        let model = engine.model();
        let feats = traffic.utterance(0, 0);

        // craft non-finite statistics (a malformed request payload) and
        // inject them directly at the batcher boundary
        let mut stats = model.utt_stats(&feats);
        for n in stats.n.iter_mut() {
            *n = f64::NAN;
        }
        let (tx, rx) = sync_channel(1);
        let deadline = Instant::now() + Duration::from_secs(5);
        engine.batcher.submit(stats, Arc::clone(&model), tx, deadline, deadline).unwrap();
        // the dispatch panics inside the E-step; the catch_unwind path
        // drops the job, closing the response channel
        assert!(rx.recv().is_err(), "poisoned request must error, not produce an i-vector");

        // same single worker, next request: still alive and bit-correct
        let got = engine.extract(&feats).unwrap();
        let want = model.extract_serial(&feats);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-10 * (1.0 + w.abs()), "coord {j}: {g} vs {w}");
        }
        let m = engine.metrics();
        assert_eq!(m.shed_requests, 0);
        assert_eq!(m.extract.count, 1);
    }

    #[test]
    fn verify_load_sustains_a_thousand_requests() {
        // acceptance: ≥ 1000 verify requests against a tiny-config
        // engine with micro-batching on
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 6, 42);
        let engine = Engine::new(shared_bundle().clone(), &cfg.serve).unwrap();
        let report = super::super::bench::run_verify_load(
            &engine,
            &traffic,
            &super::super::bench::ServeBenchOpts {
                speakers: 6,
                enroll_utts: 2,
                requests: 1000,
                concurrency: 8,
            },
        )
        .unwrap();
        assert_eq!(report.verify.count, 1000);
        assert!(report.throughput_rps > 0.0);
        assert!(report.verify.p99_s >= report.verify.p50_s);
        assert!(
            report.target_mean > report.impostor_mean,
            "target mean {} vs impostor mean {}",
            report.target_mean,
            report.impostor_mean
        );
    }

    /// Tentpole acceptance: per-stage latency histograms and per-request
    /// traces cover the serving path — every verify leaves align /
    /// admit-wait / queue-wait / estep / backend-project samples, and
    /// each completed trace's stage sum is bounded by its end-to-end
    /// latency.
    #[test]
    fn stage_histograms_and_traces_cover_the_request_path() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 35);
        let engine = Engine::new(shared_bundle().clone(), &opts(4, 300, 2)).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        for k in 1..5 {
            engine.verify(&id, &traffic.utterance(0, k)).unwrap();
        }

        let stages = engine.obs().stage_summaries();
        let get = |name: &str| stages.iter().find(|(n, _)| *n == name).unwrap().1;
        // 1 enroll + 4 verifies = 5 extractions through the full path
        assert_eq!(get("align").count, 5);
        assert_eq!(get("admit_wait").count, 5);
        assert_eq!(get("queue_wait").count, 5);
        let estep = get("estep_batch");
        assert!(estep.count >= 1 && estep.count <= 5, "batches {}", estep.count);
        assert_eq!(get("backend_project").count, 4, "one projection per verify");
        // volatile registry: no WAL stages on this path
        assert_eq!(get("wal_append").count, 0);

        // default threshold (0 ms) keeps every completed trace
        let traces = engine.obs().slow_traces();
        assert_eq!(traces.len(), 5);
        for t in &traces {
            assert_eq!(t.outcome, TraceOutcome::Ok);
            assert!(t.hops.is_empty(), "standalone engine records no replica hops");
            assert!(t.stage_ns[Stage::Align.index()] > 0, "align time must land: {t:?}");
            assert!(t.stage_ns[Stage::EstepBatch.index()] > 0, "estep time must land: {t:?}");
            assert!(
                t.stage_sum_ns() <= t.total_ns,
                "stage sum {} exceeds end-to-end {} for {t:?}",
                t.stage_sum_ns(),
                t.total_ns
            );
        }
        // request ids are unique and monotone in completion order here
        for w in traces.windows(2) {
            assert!(w[1].id > w[0].id);
        }

        // the whole thing exports: snapshot validates with all canonical
        // names present (the engine registered every one of them)
        let json = engine.obs().render(crate::obs::RenderFormat::Json);
        crate::obs::validate_snapshot(&json).expect("engine snapshot validates");
    }

    /// Satellite acceptance: `drain` finishes in-flight work, joins the
    /// worker pool, and turns every later submit into a typed
    /// `ShuttingDown` error — and it is idempotent, so the drop path
    /// can run it again without blocking.
    #[test]
    fn drain_joins_workers_and_rejects_new_submits() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 31);
        let engine = Engine::new(shared_bundle().clone(), &opts(2, 300, 2)).unwrap();
        let feats = traffic.utterance(0, 0);
        let want = engine.model().extract_serial(&feats);

        // a request already queued when the drain starts must complete
        let pre_drain = std::thread::scope(|scope| {
            let engine = &engine;
            let feats = &feats;
            let h = scope.spawn(move || engine.extract(feats));
            // wait until the request is admitted (queued or dispatched)
            let t0 = Instant::now();
            while engine.metrics().batched_requests == 0 && engine.queue_len() == 0 {
                assert!(t0.elapsed() < Duration::from_secs(10), "request never queued");
                std::thread::sleep(Duration::from_millis(1));
            }
            assert!(engine.drain(Duration::from_secs(10)), "workers must join");
            h.join().unwrap()
        });
        // the in-flight request either completed bit-correctly or — if
        // the drain flag won the race before submit — was typed-shed
        match pre_drain {
            Ok(got) => {
                for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert!((g - w).abs() <= 1e-10 * (1.0 + w.abs()), "coord {j}: {g} vs {w}");
                }
            }
            Err(e) => {
                let typed = e.downcast_ref::<ServeError>().expect("typed serve error");
                assert!(matches!(typed, ServeError::ShuttingDown), "{typed:?}");
            }
        }

        assert!(engine.is_draining());
        // new submits after drain: typed ShuttingDown, fast (no queue wait)
        let t0 = Instant::now();
        let err = engine.extract(&traffic.utterance(0, 1)).unwrap_err();
        let typed = err.downcast_ref::<ServeError>().expect("typed serve error");
        assert!(matches!(typed, ServeError::ShuttingDown), "{typed:?}");
        assert!(!typed.is_rejection(), "shutdown is not an overload rejection");
        assert!(t0.elapsed() < Duration::from_secs(1), "shutdown must fail fast");
        let err = engine.enroll("spk", &traffic.utterance(0, 2)).unwrap_err();
        assert!(err.downcast_ref::<ServeError>().is_some(), "{err}");

        // idempotent: a second drain (and the drop path after it)
        // returns immediately with nothing left to join
        assert!(engine.drain(Duration::from_millis(10)));
    }

    /// Enrollments made through a durable-registry engine are on the WAL
    /// and come back — profile-identical — when a fresh engine opens the
    /// same storage, and the counters surface through `EngineMetrics`.
    #[test]
    fn engine_on_durable_registry_survives_reopen() {
        use super::super::registry::{DurableRegistry, DurableRegistryOptions, MemStorage};

        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 61);
        let store = MemStorage::new();
        let dopts = DurableRegistryOptions { shards: 4, ..Default::default() };
        let open = |store: &MemStorage| {
            DurableRegistry::with_storage(Box::new(store.clone()), &dopts).unwrap()
        };

        let id = traffic.speaker_id(0);
        let (want_profile, fingerprint) = {
            let durable = open(&store);
            let engine =
                Engine::with_registry(shared_bundle().clone(), &opts(2, 300, 1), durable.handle())
                    .unwrap();
            engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
            engine.enroll(&id, &traffic.utterance(0, 1)).unwrap();
            let m = engine.metrics();
            assert!(m.durability.wal_enabled);
            assert_eq!(m.durability.wal_appends, 2);
            assert_eq!(m.enrolled_speakers, 1);
            (engine.registry().profile(&id).unwrap(), engine.model().fingerprint)
        };

        // "process restart": a fresh engine over recovered storage
        let durable = open(&store);
        assert_eq!(durable.recovery().replayed, 2);
        let engine =
            Engine::with_registry(shared_bundle().clone(), &opts(2, 300, 1), durable.handle())
                .unwrap();
        let p = engine.registry().profile(&id).expect("enrollment must survive the restart");
        assert_eq!(p, want_profile);
        assert_eq!(p.model_fp, fingerprint, "the model tag survives too");
        // and the recovered profile verifies against the same bundle
        engine.verify(&id, &traffic.utterance(0, 9)).unwrap();
        assert_eq!(engine.metrics().durability.replayed, 2);
    }

    /// Tentpole acceptance (engine level): an utterance fed chunk by
    /// chunk through a session scores identically (≤ 1e-10) to the
    /// one-shot `verify` of the same frames, the session stages land in
    /// the obs layer, and a closed session answers typed.
    #[test]
    fn session_feed_and_score_match_one_shot_verify() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 71);
        let engine = Engine::new(shared_bundle().clone(), &opts(4, 300, 2)).unwrap();
        let id = traffic.speaker_id(0);
        for k in 0..2 {
            engine.enroll(&id, &traffic.utterance(0, k)).unwrap();
        }
        let utt = traffic.utterance(0, 50);
        let want = engine.verify(&id, &utt).unwrap();

        let sid = engine.session_open(&id).unwrap();
        let mut fed = 0;
        let mut lo = 0;
        while lo < utt.rows() {
            let hi = (lo + 23).min(utt.rows());
            match engine.session_feed(sid, &chunk_of(&utt, lo, hi)).unwrap() {
                FeedOutcome::Pending { frames } => fed = frames,
                FeedOutcome::Decided { .. } => panic!("no early-exit thresholds configured"),
            }
            lo = hi;
        }
        assert_eq!(fed, utt.rows());

        // interim score (session stays open) and final close both match
        let interim = engine.session_score(sid).unwrap();
        assert!(
            (interim.score - want.score).abs() <= 1e-10 * (1.0 + want.score.abs()),
            "streaming {} vs one-shot {}",
            interim.score,
            want.score
        );
        assert_eq!(interim.enrolled_utts, 2);
        let fin = engine.session_close(sid).unwrap();
        assert!((fin.score - want.score).abs() <= 1e-10 * (1.0 + want.score.abs()));

        // the tombstone answers typed; an unknown id stays distinct
        let err = engine.session_feed(sid, &chunk_of(&utt, 0, 5)).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionClosed)),
            "{err}"
        );
        let err = engine.session_score(987_654).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionNotFound)),
            "{err}"
        );

        // the streaming stages land next to the one-shot ones
        let stages = engine.obs().stage_summaries();
        let get = |name: &str| stages.iter().find(|(n, _)| *n == name).unwrap().1;
        assert!(get("session_feed").count >= 3, "one sample per fed chunk");
        assert_eq!(get("session_score").count, 2, "interim + close");
        let m = engine.metrics();
        assert_eq!(m.sessions_opened, 1);
        assert_eq!(m.live_sessions, 0);
        assert_eq!(m.session_early_exits, 0);
        // and the whole thing still exports a valid snapshot
        let json = engine.obs().render(crate::obs::RenderFormat::Json);
        crate::obs::validate_snapshot(&json).expect("session-bearing snapshot validates");
    }

    /// Tentpole acceptance (early exit): a confident interim score
    /// finalizes the session mid-utterance, consuming fewer frames than
    /// the full utterance; the decision and the counters are typed and
    /// exact, and the reject threshold fires symmetrically.
    #[test]
    fn session_early_exit_decides_before_utterance_end() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 83);
        let mut o = opts(4, 300, 2);
        o.session.min_frames = 30;
        // a threshold every score clears: the decision must fire on the
        // first feed at/past min_frames, deterministically
        o.session.accept_score = Some(-1e9);
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        let utt = traffic.utterance(0, 40);
        assert!(utt.rows() >= 60, "tiny corpus guarantees ≥ 60 frames");

        let sid = engine.session_open(&id).unwrap();
        let mut decided = None;
        let mut lo = 0;
        while lo < utt.rows() {
            let hi = (lo + 20).min(utt.rows());
            match engine.session_feed(sid, &chunk_of(&utt, lo, hi)).unwrap() {
                FeedOutcome::Pending { frames } => assert!(frames < 30, "must decide at 30+"),
                FeedOutcome::Decided { score, frames, accepted } => {
                    decided = Some((score, frames, accepted));
                    break;
                }
            }
            lo = hi;
        }
        let (_, frames, accepted) = decided.expect("the accept threshold must fire");
        assert!(accepted);
        assert_eq!(frames, 40, "two 20-frame chunks reach min_frames=30");
        assert!(frames < utt.rows(), "early exit must beat the utterance end");
        // the decision closed the session
        let err = engine.session_score(sid).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionClosed)),
            "{err}"
        );
        let m = engine.metrics();
        assert_eq!(m.session_early_exits, 1);
        assert_eq!(m.live_sessions, 0);

        // the reject threshold fires the other way
        let mut o = opts(4, 300, 2);
        o.session.min_frames = 30;
        o.session.reject_score = Some(1e9);
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        let sid = engine.session_open(&id).unwrap();
        engine.session_feed(sid, &chunk_of(&utt, 0, 20)).unwrap();
        match engine.session_feed(sid, &chunk_of(&utt, 20, 40)).unwrap() {
            FeedOutcome::Decided { accepted, frames, .. } => {
                assert!(!accepted);
                assert_eq!(frames, 40);
            }
            other => panic!("reject threshold must decide, got {other:?}"),
        }
        assert_eq!(engine.metrics().session_early_exits, 1);
    }

    /// Satellite acceptance (session-vs-swap, engine half): a hot swap
    /// mid-session leaves the session scoring on its pinned
    /// fingerprint — same score before and after the swap — while
    /// one-shot requests move to the new model; a re-enrollment under
    /// the new model turns later session scores into a typed refusal,
    /// never a cross-space score.
    #[test]
    fn session_pins_model_across_hot_swap() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 29);
        let bundle = shared_bundle().clone();
        let engine = Engine::new(bundle.clone(), &opts(4, 300, 2)).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        let utt = traffic.utterance(0, 10);

        let sid = engine.session_open(&id).unwrap();
        engine.session_feed(sid, &chunk_of(&utt, 0, utt.rows() / 2)).unwrap();
        let before = engine.session_score(sid).unwrap().score;

        // a retrained-model stand-in: same dims, different parameters
        let mut other = bundle;
        *other.tvm.t[0].get_mut(0, 0) += 0.5;
        engine.swap_bundle(other).unwrap();

        // one-shot verify now refuses the stale profile...
        let err = engine.verify(&id, &utt).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // ...but the session keeps feeding and scoring on its pinned
        // snapshot: the mid-stream score is byte-stable across the swap
        let after = engine.session_score(sid).unwrap().score;
        assert_eq!(before, after, "pinned session score must not move on swap");
        engine.session_feed(sid, &chunk_of(&utt, utt.rows() / 2, utt.rows())).unwrap();
        engine.session_close(sid).unwrap();

        // a new session can't open against the stale profile...
        let err = engine.session_open(&id).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // ...and once the speaker re-enrolls under the new model, an
        // old-space session (pinned pre-swap) is refused typed — two
        // total-variability spaces never meet in one score
        let engine2 = Engine::new(shared_bundle().clone(), &opts(4, 300, 2)).unwrap();
        engine2.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        let sid2 = engine2.session_open(&id).unwrap();
        engine2.session_feed(sid2, &chunk_of(&utt, 0, 30)).unwrap();
        let mut other = shared_bundle().clone();
        *other.tvm.t[0].get_mut(0, 0) -= 0.25;
        engine2.swap_bundle(other).unwrap();
        engine2.registry().remove(&id).unwrap();
        engine2.enroll(&id, &traffic.utterance(0, 1)).unwrap();
        let err = engine2.session_score(sid2).unwrap_err();
        assert!(err.to_string().contains("re-enrolled"), "{err}");
    }

    /// Admission and idle eviction are typed: the table bound sheds
    /// opens with `SessionLimit` (a rejection, like a queue shed), and
    /// an idled session is reclaimed — lazily on touch or by the sweep
    /// — surfacing as `SessionExpired` with the eviction counted.
    #[test]
    fn session_limit_sheds_and_idle_sessions_evict_typed() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 37);
        let mut o = opts(4, 300, 2);
        o.session.max_sessions = 1;
        o.session.idle_ms = 40;
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();

        let sid = engine.session_open(&id).unwrap();
        let err = engine.session_open(&id).unwrap_err();
        let typed = err.downcast_ref::<ServeError>().expect("typed serve error");
        assert!(matches!(typed, ServeError::SessionLimit { live: 1 }), "{typed:?}");
        assert!(typed.is_rejection());
        assert_eq!(engine.metrics().session_shed, 1);

        // past the idle deadline the next touch reclaims it typed
        std::thread::sleep(Duration::from_millis(60));
        let err = engine.session_feed(sid, &traffic.utterance(0, 1)).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<ServeError>(), Some(ServeError::SessionExpired)),
            "{err}"
        );
        let m = engine.metrics();
        assert_eq!(m.session_evictions, 1);
        assert_eq!(m.live_sessions, 0);

        // the freed slot admits again, and the open-path sweep reclaims
        // an idled session without any touch
        let sid2 = engine.session_open(&id).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let sid3 = engine.session_open(&id).expect("sweep on open frees the slot");
        assert_ne!(sid2, sid3);
        assert_eq!(engine.metrics().session_evictions, 2);
        engine.session_close(sid3).unwrap();
    }

    /// Satellite acceptance (capture under overload): with workers
    /// stalled and the queue saturated, shed and timed-out requests
    /// land in the capture log with their *typed* outcome — the
    /// corpus records what the engine actually did under pressure,
    /// not just the happy path — and the recorder offer never blocks
    /// admission: shed threads return on the submit deadline, not the
    /// capture sink's schedule.
    #[test]
    fn capture_records_typed_outcomes_under_overload_without_blocking() {
        use super::super::capture::{CaptureLog, RecorderOptions};
        use super::super::registry::MemStorage;

        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 41);
        let mut o = opts(2, 200, 1);
        o.queue_cap = 1;
        o.submit_timeout_ms = 20;
        o.request_timeout_ms = 300;
        let engine = Engine::new(shared_bundle().clone(), &o).unwrap();
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();

        let store = MemStorage::new();
        let log = CaptureLog::create(Box::new(store.clone()), engine.model().fingerprint)
            .unwrap();
        let recorder = Recorder::new(log, &RecorderOptions::default(), engine.obs());
        engine.set_recorder(Some(Arc::clone(&recorder)));

        engine.stall_workers(true);
        let n = 6;
        let results: Vec<(bool, Duration)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n)
                .map(|i| {
                    let engine = &engine;
                    let traffic = &traffic;
                    let id = &id;
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let r = engine.verify(id, &traffic.utterance(0, i as u64 + 1));
                        (r.is_err(), t0.elapsed())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        engine.stall_workers(false);
        engine.set_recorder(None);
        let summary = recorder.close();

        // every request failed typed under the stall; the sheds came
        // back on the admission deadline (20 ms + generous slack) —
        // capture added no synchronous work to the request thread
        assert!(results.iter().all(|(failed, _)| *failed));
        let fast = results.iter().filter(|(_, d)| *d < Duration::from_millis(200)).count();
        assert!(fast >= n - 1, "expected ≥{} shed fast, got {fast}", n - 1);
        assert_eq!(summary.dropped, 0, "roomy queue: nothing should drop");
        assert!(summary.write_error.is_none(), "{:?}", summary.write_error);

        let replay = CaptureLog::load(&store).unwrap();
        assert!(!replay.torn_tail);
        assert!(replay
            .records
            .iter()
            .any(|r| r.kind == RequestKind::Enroll && r.outcome == TraceOutcome::Ok));
        let verifies: Vec<_> =
            replay.records.iter().filter(|r| r.kind == RequestKind::Verify).collect();
        assert_eq!(verifies.len(), n, "all overloaded verifies captured");
        assert!(verifies.iter().all(|r| r.outcome != TraceOutcome::Ok && r.score.is_none()));
        assert!(
            verifies.iter().any(|r| r.outcome == TraceOutcome::Shed),
            "queue cap 1 with {n} concurrent verifies must shed some typed"
        );
    }
}
