//! The serving engine: an immutable, atomically hot-swappable model
//! bundle behind `extract` / `enroll` / `verify`.
//!
//! Request flow (the paper's Fig. 1 pipeline reshaped for serving):
//! the request thread plays the CPU-loader role — alignment + Baum-Welch
//! statistics against its model snapshot — then parks on a response
//! channel while the micro-batcher coalesces concurrent requests into
//! one GEMM-shaped E-step dispatch. Enrollments land in the sharded
//! [`Registry`]; verification scores the averaged enrollment i-vector
//! against the request's i-vector through the bundle's PLDA backend.

use std::sync::mpsc::sync_channel;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::ServeConfig;
use crate::linalg::Mat;
use crate::metrics::{LatencyHistogram, LatencySummary};

use super::batcher::MicroBatcher;
use super::bundle::{ModelBundle, ServeModel};
use super::registry::Registry;

/// One verification result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VerifyOutcome {
    /// PLDA log-likelihood ratio (higher = more likely the claimed
    /// speaker; threshold-free, like the offline `eval` scores).
    pub score: f64,
    /// Enrollment utterances behind the claimed speaker's profile.
    pub enrolled_utts: u64,
}

/// Point-in-time engine counters.
#[derive(Debug, Clone, Copy)]
pub struct EngineMetrics {
    pub uptime_s: f64,
    pub extract: LatencySummary,
    pub enroll: LatencySummary,
    pub verify: LatencySummary,
    pub dispatched_batches: u64,
    pub batched_requests: u64,
    pub enrolled_speakers: usize,
}

impl EngineMetrics {
    /// Mean requests per dispatched E-step batch.
    pub fn mean_batch(&self) -> f64 {
        if self.dispatched_batches == 0 {
            return 0.0;
        }
        self.batched_requests as f64 / self.dispatched_batches as f64
    }
}

/// The long-lived serving engine. `&Engine` is `Sync`: request threads
/// call `extract`/`enroll`/`verify` concurrently while an operator
/// thread may [`Engine::swap_bundle`] at any time.
pub struct Engine {
    /// The current model, swapped atomically; requests snapshot the
    /// `Arc` once and stay on that snapshot end-to-end.
    model: RwLock<Arc<ServeModel>>,
    registry: Registry,
    batcher: MicroBatcher,
    extract_lat: LatencyHistogram,
    enroll_lat: LatencyHistogram,
    verify_lat: LatencyHistogram,
    started: Instant,
}

impl Engine {
    /// Spin up the worker pool around a bundle.
    pub fn new(bundle: ModelBundle, opts: &ServeConfig) -> Self {
        Self {
            model: RwLock::new(Arc::new(ServeModel::new(bundle))),
            registry: Registry::new(opts.registry_shards),
            batcher: MicroBatcher::new(
                opts.batch_utts,
                Duration::from_micros(opts.flush_us),
                opts.workers,
                opts.queue_cap,
            ),
            extract_lat: LatencyHistogram::new(),
            enroll_lat: LatencyHistogram::new(),
            verify_lat: LatencyHistogram::new(),
            started: Instant::now(),
        }
    }

    /// Snapshot the current model.
    pub fn model(&self) -> Arc<ServeModel> {
        self.model.read().unwrap().clone()
    }

    /// Atomically replace the model bundle. In-flight requests finish
    /// on the snapshot they started with; the micro-batcher never mixes
    /// snapshots within a batch.
    pub fn swap_bundle(&self, bundle: ModelBundle) {
        let next = Arc::new(ServeModel::new(bundle));
        *self.model.write().unwrap() = next;
    }

    /// The speaker registry (persistence, admin).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Extraction against an explicit snapshot — the shared inner path.
    fn extract_with(&self, model: &Arc<ServeModel>, feats: &Mat) -> Result<Vec<f64>> {
        // announce before the loader work so batch workers know a
        // co-rider is on the way and hold sub-size batches for it
        let token = self.batcher.begin_request();
        let stats = model.utt_stats(feats);
        let (tx, rx) = sync_channel(1);
        self.batcher.submit(stats, Arc::clone(model), tx)?;
        drop(token); // queued: no longer "on the way"
        rx.recv().map_err(|_| anyhow!("serving worker dropped the response"))
    }

    /// Extract one i-vector for a feature matrix (frames × dim).
    pub fn extract(&self, feats: &Mat) -> Result<Vec<f64>> {
        let t0 = Instant::now();
        let model = self.model();
        let iv = self.extract_with(&model, feats)?;
        self.extract_lat.record(t0.elapsed().as_secs_f64());
        Ok(iv)
    }

    /// Enroll one utterance for a speaker (averaged with any previous
    /// enrollments); returns the speaker's new utterance count. The
    /// profile is tagged with the model fingerprint, so enrollments
    /// never mix models across a hot swap.
    pub fn enroll(&self, speaker_id: &str, feats: &Mat) -> Result<u64> {
        let t0 = Instant::now();
        let model = self.model();
        let iv = self.extract_with(&model, feats)?;
        let count = self.registry.enroll(speaker_id, &iv, model.fingerprint)?;
        self.enroll_lat.record(t0.elapsed().as_secs_f64());
        Ok(count)
    }

    /// Verify an utterance against an enrolled speaker. Refuses to
    /// score a profile enrolled under a different model than the
    /// current bundle — i-vectors from different total-variability
    /// spaces are not comparable, so the mismatch is an error rather
    /// than a plausible-looking meaningless score.
    pub fn verify(&self, speaker_id: &str, feats: &Mat) -> Result<VerifyOutcome> {
        let t0 = Instant::now();
        let model = self.model();
        let profile = self
            .registry
            .profile(speaker_id)
            .ok_or_else(|| anyhow!("speaker `{speaker_id}` is not enrolled"))?;
        anyhow::ensure!(
            profile.model_fp == model.fingerprint,
            "speaker `{speaker_id}` was enrolled under a different model — \
             re-enroll after the bundle swap"
        );
        let iv = self.extract_with(&model, feats)?;
        let score = model.score(&profile.mean(), &iv);
        self.verify_lat.record(t0.elapsed().as_secs_f64());
        Ok(VerifyOutcome { score, enrolled_utts: profile.count })
    }

    /// Counters snapshot.
    pub fn metrics(&self) -> EngineMetrics {
        EngineMetrics {
            uptime_s: self.started.elapsed().as_secs_f64(),
            extract: self.extract_lat.summary(),
            enroll: self.enroll_lat.summary(),
            verify: self.verify_lat.summary(),
            dispatched_batches: self.batcher.dispatched_batches(),
            batched_requests: self.batcher.batched_requests(),
            enrolled_speakers: self.registry.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Mutex, OnceLock};

    use super::super::bench::{tiny_serve_config, tiny_traffic, train_tiny_bundle};
    use super::*;
    use crate::ivector::extract_cpu;

    /// One tiny bundle shared across the serve tests (training it takes
    /// a few seconds; every test needs the same deterministic model).
    fn shared_bundle() -> &'static ModelBundle {
        static BUNDLE: OnceLock<ModelBundle> = OnceLock::new();
        BUNDLE.get_or_init(|| train_tiny_bundle(&tiny_serve_config(), 5).unwrap())
    }

    fn opts(batch_utts: usize, flush_us: u64, workers: usize) -> ServeConfig {
        ServeConfig { batch_utts, flush_us, workers, registry_shards: 4, queue_cap: 256 }
    }

    #[test]
    fn prop_serve_extraction_matches_extract_cpu() {
        // acceptance: batched serve-path extraction ≡ extract_cpu on the
        // same features (≤ 1e-10)
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 77);
        let engine = Engine::new(shared_bundle().clone(), &opts(4, 300, 2));
        let model = engine.model();
        crate::proptest::forall(
            20_2507,
            16,
            |rng| {
                let s = rng.below(4);
                let k = rng.below(64) as u64;
                (s, k)
            },
            |&(s, k)| {
                let feats = traffic.utterance(s, k);
                let got = engine.extract(&feats).map_err(|e| e.to_string())?;
                let stats = model.utt_stats(&feats);
                let want = extract_cpu(&model.bundle.tvm, std::slice::from_ref(&stats), 1);
                for (j, (g, w)) in got.iter().zip(want.row(0)).enumerate() {
                    if (g - w).abs() > 1e-10 * (1.0 + w.abs()) {
                        return Err(format!("coord {j}: {g} vs {w}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn concurrent_requests_coalesce_into_batches() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 4, 13);
        // one worker + generous deadline: near-simultaneous requests
        // must ride shared dispatches
        let engine = Engine::new(shared_bundle().clone(), &opts(8, 200_000, 1));
        let n = 16;
        let barrier = std::sync::Barrier::new(n);
        std::thread::scope(|scope| {
            for i in 0..n {
                let engine = &engine;
                let traffic = &traffic;
                let barrier = &barrier;
                scope.spawn(move || {
                    let feats = traffic.utterance(i % 4, i as u64);
                    barrier.wait();
                    engine.extract(&feats).unwrap();
                });
            }
        });
        let m = engine.metrics();
        assert_eq!(m.batched_requests, 16);
        assert!(m.dispatched_batches >= 2, "batches {}", m.dispatched_batches);
        // inbound-aware early flush makes exact batch counts scheduling
        // dependent; requiring strictly fewer batches than requests
        // still proves coalescing happened
        assert!(
            m.dispatched_batches < 16,
            "16 near-simultaneous requests should coalesce, got {} batches",
            m.dispatched_batches
        );
        assert_eq!(m.extract.count, 16);
    }

    #[test]
    fn verify_after_incompatible_swap_is_rejected() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 17);
        let bundle = shared_bundle().clone();
        let engine = Engine::new(bundle.clone(), &opts(2, 300, 1));
        let id = traffic.speaker_id(0);
        engine.enroll(&id, &traffic.utterance(0, 0)).unwrap();
        // a value-identical swap keeps the profile scorable
        engine.swap_bundle(bundle.clone());
        engine.verify(&id, &traffic.utterance(0, 1)).unwrap();
        // a retrained-model stand-in: same dims, different parameters
        let mut other = bundle;
        *other.tvm.t[0].get_mut(0, 0) += 0.5;
        engine.swap_bundle(other);
        let err = engine.verify(&id, &traffic.utterance(0, 1)).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // mixing epochs within one profile is refused too
        let err = engine.enroll(&id, &traffic.utterance(0, 2)).unwrap_err();
        assert!(err.to_string().contains("different model"), "{err}");
        // removing the stale profile unblocks enrollment under the new model
        assert!(engine.registry().remove(&id));
        engine.enroll(&id, &traffic.utterance(0, 2)).unwrap();
        engine.verify(&id, &traffic.utterance(0, 3)).unwrap();
    }

    #[test]
    fn unknown_speaker_is_rejected() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 1, 3);
        let engine = Engine::new(shared_bundle().clone(), &opts(2, 200, 1));
        let err = engine.verify("nobody", &traffic.utterance(0, 0)).unwrap_err();
        assert!(err.to_string().contains("not enrolled"), "{err}");
    }

    #[test]
    fn verify_scores_separate_target_from_impostor() {
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 2, 21);
        let engine = Engine::new(shared_bundle().clone(), &opts(4, 500, 2));
        let id = traffic.speaker_id(0);
        for k in 0..3 {
            engine.enroll(&id, &traffic.utterance(0, k)).unwrap();
        }
        // mean over several trials — a single pair at tiny dims is noisy
        let mut target = 0.0;
        let mut impostor = 0.0;
        for k in 50..56 {
            let t = engine.verify(&id, &traffic.utterance(0, k)).unwrap();
            assert_eq!(t.enrolled_utts, 3);
            target += t.score;
            impostor += engine.verify(&id, &traffic.utterance(1, k)).unwrap().score;
        }
        assert!(
            target > impostor,
            "mean target {} must out-score mean impostor {}",
            target / 6.0,
            impostor / 6.0
        );
    }

    /// Satellite acceptance: N threads enroll/verify against one engine
    /// while hot swaps replace the bundle mid-flight — no lost
    /// enrollments, scores identical to the single-threaded oracle.
    #[test]
    fn concurrent_enroll_verify_with_hot_swap_matches_oracle() {
        let cfg = tiny_serve_config();
        let bundle = shared_bundle().clone();
        let oracle = ServeModel::new(bundle.clone());
        // speakers 0..8 owned by the worker threads; 8 is the shared
        // contended speaker every thread also enrolls
        let traffic = tiny_traffic(&cfg, 9, 99);
        let engine = Engine::new(bundle.clone(), &opts(4, 1_000, 2));
        let n_threads = 4usize;
        let enroll_utts = 2usize;
        let running = AtomicBool::new(true);
        let scores: Mutex<Vec<(usize, f64, f64)>> = Mutex::new(Vec::new());

        std::thread::scope(|scope| {
            // hot-swapper: replaces the bundle (with identical values)
            // while requests are in flight
            let swapper = {
                let engine = &engine;
                let bundle = &bundle;
                let running = &running;
                scope.spawn(move || {
                    while running.load(Ordering::Relaxed) {
                        engine.swap_bundle(bundle.clone());
                        std::thread::sleep(Duration::from_millis(1));
                    }
                })
            };
            let handles: Vec<_> = (0..n_threads)
                .map(|t| {
                    let engine = &engine;
                    let traffic = &traffic;
                    let scores = &scores;
                    scope.spawn(move || {
                        for rep in 0..2 {
                            let spk = t * 2 + rep;
                            let id = traffic.speaker_id(spk);
                            for k in 0..enroll_utts {
                                engine.enroll(&id, &traffic.utterance(spk, k as u64)).unwrap();
                            }
                            // contended speaker: identical utterance from
                            // every thread, so the running sum is exact
                            // in any interleaving
                            engine.enroll("shared", &traffic.utterance(8, 0)).unwrap();
                            let target =
                                engine.verify(&id, &traffic.utterance(spk, 100)).unwrap();
                            let impostor = engine
                                .verify(&id, &traffic.utterance((spk + 1) % 8, 100))
                                .unwrap();
                            scores.lock().unwrap().push((spk, target.score, impostor.score));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            running.store(false, Ordering::Relaxed);
            swapper.join().unwrap();
        });

        // no lost enrollments under contention
        let reg = engine.registry();
        assert_eq!(reg.len(), 9, "8 per-thread speakers + the shared one");
        assert_eq!(
            reg.profile("shared").unwrap().count,
            (n_threads * 2) as u64,
            "every thread's shared enrollments must land"
        );
        assert_eq!(reg.total_enrollments(), (8 * enroll_utts + n_threads * 2) as u64);
        for spk in 0..8 {
            assert_eq!(
                reg.profile(&traffic.speaker_id(spk)).unwrap().count,
                enroll_utts as u64
            );
        }

        // scores identical to the single-threaded oracle
        let results = scores.into_inner().unwrap();
        assert_eq!(results.len(), 8);
        for (spk, target, impostor) in results {
            let mut sum = vec![0.0; oracle.rank()];
            for k in 0..enroll_utts {
                let iv = oracle.extract_serial(&traffic.utterance(spk, k as u64));
                for (s, x) in sum.iter_mut().zip(&iv) {
                    *s += x;
                }
            }
            let mean: Vec<f64> = sum.iter().map(|&x| x / enroll_utts as f64).collect();
            let want_t =
                oracle.score(&mean, &oracle.extract_serial(&traffic.utterance(spk, 100)));
            let want_i = oracle.score(
                &mean,
                &oracle.extract_serial(&traffic.utterance((spk + 1) % 8, 100)),
            );
            assert!(
                (target - want_t).abs() <= 1e-12 * (1.0 + want_t.abs()),
                "spk {spk}: target {target} vs oracle {want_t}"
            );
            assert!(
                (impostor - want_i).abs() <= 1e-12 * (1.0 + want_i.abs()),
                "spk {spk}: impostor {impostor} vs oracle {want_i}"
            );
        }
    }

    #[test]
    fn verify_load_sustains_a_thousand_requests() {
        // acceptance: ≥ 1000 verify requests against a tiny-config
        // engine with micro-batching on
        let cfg = tiny_serve_config();
        let traffic = tiny_traffic(&cfg, 6, 42);
        let engine = Engine::new(shared_bundle().clone(), &cfg.serve);
        let report = super::super::bench::run_verify_load(
            &engine,
            &traffic,
            &super::super::bench::ServeBenchOpts {
                speakers: 6,
                enroll_utts: 2,
                requests: 1000,
                concurrency: 8,
            },
        )
        .unwrap();
        assert_eq!(report.verify.count, 1000);
        assert!(report.throughput_rps > 0.0);
        assert!(report.verify.p99_s >= report.verify.p50_s);
        assert!(
            report.target_mean > report.impostor_mean,
            "target mean {} vs impostor mean {}",
            report.target_mean,
            report.impostor_mean
        );
    }
}
