//! # ivector-tv
//!
//! A three-layer reproduction of *"Unleashing the Unused Potential of
//! I-Vectors Enabled by GPU Acceleration"* (Vestman, Lee, Kinnunen,
//! Koshinaka — Interspeech 2019).
//!
//! * **L3 (this crate)** — the coordinator: EM training schedule with
//!   in-training realignment, pipelined CPU data loaders feeding the
//!   accelerator, ensemble runner, scoring backend, CLI, and the online
//!   serving subsystem ([`serve`]: micro-batched extraction, speaker
//!   registry, verification engine).
//! * **L2** — JAX compute graphs (frame alignment, TVM E-step, i-vector
//!   extraction, UBM accumulation, PLDA scoring), AOT-lowered to HLO text
//!   at build time (`python/compile/`).
//! * **L1** — Pallas kernels for the hot spots inside the L2 graphs.
//!
//! Python never runs on the request path: the rust binary loads the
//! HLO artifacts through PJRT ([`runtime`]) and is self-contained.

// The `simd` feature swaps the f32 alignment kernels' inner loops for
// explicit `std::simd` lanes (nightly-only; the default build uses
// 8-wide unrolled loops that auto-vectorize on stable).
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod bench_util;
pub mod config;
pub mod exec;
pub mod frontend;
pub mod gmm;
pub mod io;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod trials;
pub mod backend;
pub mod cli;
pub mod coordinator;
pub mod ivector;
