//! Feature and posterior archives — the Kaldi `.ark` analogues.
//!
//! * [`FeatArchive`] stores per-utterance feature matrices (f32 payload,
//!   frames × dim) with utterance and speaker ids.
//! * [`PostArchive`] stores the *pruned* frame posteriors the alignment
//!   stage produces: per frame, a short list of (gaussian index,
//!   posterior) pairs — the paper stores "on average four Gaussian
//!   indices and posteriors per frame" the same way.

use std::path::Path;

use anyhow::{bail, Result};

use super::{BinReader, BinWriter};
use crate::linalg::Mat;

/// One utterance: id, speaker, and its feature matrix (frames × dim).
#[derive(Debug, Clone)]
pub struct Utterance {
    pub utt_id: String,
    pub spk_id: String,
    /// Features, frames × dim (f64 in memory; stored f32 like Kaldi).
    pub feats: Mat,
}

/// Feature archive: ordered collection of utterances.
#[derive(Debug, Clone, Default)]
pub struct FeatArchive {
    pub utts: Vec<Utterance>,
}

impl FeatArchive {
    /// Write all utterances to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = BinWriter::create(path)?;
        w.write_u64(self.utts.len() as u64)?;
        for u in &self.utts {
            w.write_string(&u.utt_id)?;
            w.write_string(&u.spk_id)?;
            w.write_u32(u.feats.rows() as u32)?;
            w.write_u32(u.feats.cols() as u32)?;
            let f32s: Vec<f32> = u.feats.as_slice().iter().map(|&x| x as f32).collect();
            w.write_f32_slice(&f32s)?;
        }
        w.finish()
    }

    /// Read an archive written by [`FeatArchive::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut r = BinReader::open(&path)?;
        let n = r.read_u64()? as usize;
        let mut utts = Vec::with_capacity(n);
        for _ in 0..n {
            let utt_id = r.read_string()?;
            let spk_id = r.read_string()?;
            let rows = r.read_u32()? as usize;
            let cols = r.read_u32()? as usize;
            let data = r.read_f32_vec(rows * cols)?;
            let feats = Mat::from_vec(data.iter().map(|&x| x as f64).collect(), rows, cols);
            utts.push(Utterance { utt_id, spk_id, feats });
        }
        Ok(Self { utts })
    }

    /// Total frame count across utterances.
    pub fn total_frames(&self) -> usize {
        self.utts.iter().map(|u| u.feats.rows()).sum()
    }

    /// Feature dimension (all utterances agree; panics on empty archive).
    pub fn dim(&self) -> usize {
        self.utts[0].feats.cols()
    }

    /// Distinct speaker ids, in first-seen order.
    pub fn speakers(&self) -> Vec<String> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for u in &self.utts {
            if seen.insert(u.spk_id.clone()) {
                out.push(u.spk_id.clone());
            }
        }
        out
    }
}

/// One (gaussian index, posterior) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Posting {
    pub idx: u32,
    pub post: f32,
}

/// Pruned posteriors for one utterance: `frames[f]` lists the surviving
/// components for frame `f`.
#[derive(Debug, Clone)]
pub struct UttPosts {
    pub utt_id: String,
    pub frames: Vec<Vec<Posting>>,
}

impl UttPosts {
    /// Average postings per frame (the paper reports ≈ 4).
    pub fn avg_postings(&self) -> f64 {
        if self.frames.is_empty() {
            return 0.0;
        }
        self.frames.iter().map(|f| f.len()).sum::<usize>() as f64 / self.frames.len() as f64
    }
}

/// Sparse posterior archive.
#[derive(Debug, Clone, Default)]
pub struct PostArchive {
    pub utts: Vec<UttPosts>,
}

impl PostArchive {
    /// Write to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = BinWriter::create(path)?;
        w.write_u64(self.utts.len() as u64)?;
        for u in &self.utts {
            w.write_string(&u.utt_id)?;
            w.write_u32(u.frames.len() as u32)?;
            for frame in &u.frames {
                w.write_u32(frame.len() as u32)?;
                for p in frame {
                    w.write_u32(p.idx)?;
                    w.write_f32_slice(&[p.post])?;
                }
            }
        }
        w.finish()
    }

    /// Read an archive written by [`PostArchive::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut r = BinReader::open(&path)?;
        let n = r.read_u64()? as usize;
        let mut utts = Vec::with_capacity(n);
        for _ in 0..n {
            let utt_id = r.read_string()?;
            let nframes = r.read_u32()? as usize;
            if nframes > 1 << 24 {
                bail!("frame count {nframes} implausible — corrupt archive?");
            }
            let mut frames = Vec::with_capacity(nframes);
            for _ in 0..nframes {
                let k = r.read_u32()? as usize;
                let mut frame = Vec::with_capacity(k);
                for _ in 0..k {
                    let idx = r.read_u32()?;
                    let post = r.read_f32_vec(1)?[0];
                    frame.push(Posting { idx, post });
                }
                frames.push(frame);
            }
            utts.push(UttPosts { utt_id, frames });
        }
        Ok(Self { utts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ivtv_archive_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn demo_feats() -> FeatArchive {
        FeatArchive {
            utts: vec![
                Utterance {
                    utt_id: "spk0-utt0".into(),
                    spk_id: "spk0".into(),
                    feats: Mat::from_fn(10, 4, |i, j| (i * 4 + j) as f64 * 0.25),
                },
                Utterance {
                    utt_id: "spk1-utt0".into(),
                    spk_id: "spk1".into(),
                    feats: Mat::from_fn(7, 4, |i, j| -((i + j) as f64)),
                },
            ],
        }
    }

    #[test]
    fn feats_roundtrip() {
        let p = tmp("feats.bin");
        let a = demo_feats();
        a.save(&p).unwrap();
        let b = FeatArchive::load(&p).unwrap();
        assert_eq!(b.utts.len(), 2);
        assert_eq!(b.utts[0].utt_id, "spk0-utt0");
        assert_eq!(b.utts[1].spk_id, "spk1");
        assert!(b.utts[0].feats.approx_eq(&a.utts[0].feats, 1e-6));
        assert_eq!(b.total_frames(), 17);
        assert_eq!(b.dim(), 4);
        assert_eq!(b.speakers(), vec!["spk0".to_string(), "spk1".to_string()]);
    }

    #[test]
    fn posts_roundtrip() {
        let p = tmp("posts.bin");
        let a = PostArchive {
            utts: vec![UttPosts {
                utt_id: "u0".into(),
                frames: vec![
                    vec![Posting { idx: 3, post: 0.9 }, Posting { idx: 11, post: 0.1 }],
                    vec![Posting { idx: 5, post: 1.0 }],
                ],
            }],
        };
        a.save(&p).unwrap();
        let b = PostArchive::load(&p).unwrap();
        assert_eq!(b.utts[0].frames.len(), 2);
        assert_eq!(b.utts[0].frames[0][1], Posting { idx: 11, post: 0.1 });
        assert!((b.utts[0].avg_postings() - 1.5).abs() < 1e-9);
    }
}
