//! Buffered little-endian binary reader/writer with magic + version.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Container magic every artifact in this repo shares. Crate-visible so
/// format-aware readers (the registry snapshot codec) can recognise the
/// container without going through a `BinReader`.
pub(crate) const MAGIC: &[u8; 4] = b"IVTV";
/// Container format version stamped after [`MAGIC`].
pub(crate) const VERSION: u32 = 1;

/// Buffered writer that stamps the container header on creation.
pub struct BinWriter {
    w: BufWriter<File>,
}

impl BinWriter {
    /// Create/truncate `path` and write the header.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let f = File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        let mut w = Self { w: BufWriter::new(f) };
        w.w.write_all(MAGIC)?;
        w.write_u32(VERSION)?;
        Ok(w)
    }

    pub fn write_u32(&mut self, v: u32) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn write_u64(&mut self, v: u64) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn write_f64(&mut self, v: f64) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn write_f64_slice(&mut self, v: &[f64]) -> Result<()> {
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn write_f32_slice(&mut self, v: &[f32]) -> Result<()> {
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn write_string(&mut self, s: &str) -> Result<()> {
        self.write_u32(s.len() as u32)?;
        self.w.write_all(s.as_bytes())?;
        Ok(())
    }

    /// Flush and close.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush()?;
        Ok(())
    }

    /// Flush, then fsync the file to stable storage before closing —
    /// for writers about to `rename` the file into place as an atomic
    /// replacement: without the sync, a power loss can journal the
    /// rename while the data blocks are still unwritten, leaving a
    /// present-but-truncated file. Costs an fsync, so plain [`Self::finish`]
    /// remains the default for bulk archive writes.
    pub fn finish_synced(mut self) -> Result<()> {
        self.w.flush()?;
        self.w.get_ref().sync_all()?;
        Ok(())
    }
}

/// Buffered reader that validates the container header on open.
pub struct BinReader {
    r: BufReader<File>,
}

impl BinReader {
    /// Open `path` and check magic + version.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let f = File::open(&path)
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        let mut r = Self { r: BufReader::new(f) };
        let mut magic = [0u8; 4];
        r.r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: bad magic {:?} (not an ivector-tv file)", path.as_ref().display(), magic);
        }
        let version = r.read_u32()?;
        if version != VERSION {
            bail!("{}: unsupported version {version}", path.as_ref().display());
        }
        Ok(r)
    }

    pub fn read_u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn read_u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn read_f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }

    pub fn read_f64_vec(&mut self, n: usize) -> Result<Vec<f64>> {
        let mut bytes = vec![0u8; n * 8];
        self.r.read_exact(&mut bytes)?;
        Ok(bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn read_f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.r.read_exact(&mut bytes)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub fn read_string(&mut self) -> Result<String> {
        let n = self.read_u32()? as usize;
        if n > 1 << 20 {
            bail!("string length {n} implausible — corrupt file?");
        }
        let mut b = vec![0u8; n];
        self.r.read_exact(&mut b)?;
        Ok(String::from_utf8(b)?)
    }

    /// True when the underlying file is exhausted.
    pub fn at_eof(&mut self) -> Result<bool> {
        Ok(self.r.fill_buf()?.is_empty())
    }
}

use std::io::BufRead;

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ivtv_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn primitives_roundtrip() {
        let p = tmp("prim.bin");
        let mut w = BinWriter::create(&p).unwrap();
        w.write_u32(7).unwrap();
        w.write_u64(1 << 40).unwrap();
        w.write_f64(-2.5).unwrap();
        w.write_string("hello utt").unwrap();
        w.write_f32_slice(&[1.0, 2.0]).unwrap();
        w.finish().unwrap();

        let mut r = BinReader::open(&p).unwrap();
        assert_eq!(r.read_u32().unwrap(), 7);
        assert_eq!(r.read_u64().unwrap(), 1 << 40);
        assert_eq!(r.read_f64().unwrap(), -2.5);
        assert_eq!(r.read_string().unwrap(), "hello utt");
        assert_eq!(r.read_f32_vec(2).unwrap(), vec![1.0, 2.0]);
        assert!(r.at_eof().unwrap());
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("bad.bin");
        std::fs::write(&p, b"NOPE....").unwrap();
        assert!(BinReader::open(&p).is_err());
    }

    #[test]
    fn truncated_file_errors() {
        let p = tmp("trunc.bin");
        let mut w = BinWriter::create(&p).unwrap();
        w.write_u32(1).unwrap();
        w.finish().unwrap();
        let mut r = BinReader::open(&p).unwrap();
        r.read_u32().unwrap();
        assert!(r.read_u64().is_err());
    }
}
