//! Binary IO substrate — the Kaldi-archive analogue.
//!
//! The paper reads Kaldi-format feature/posterior archives through
//! PyKaldi; we define our own little-endian binary container with the
//! same roles: feature archives (`.feats`), sparse posterior archives
//! (`.posts`), and model files. All writers/readers go through the
//! [`BinWriter`]/[`BinReader`] primitives so every format shares magic +
//! version handling.

mod bin;
mod archive;

pub use archive::{FeatArchive, PostArchive, Posting, Utterance, UttPosts};
pub use bin::{BinReader, BinWriter};
pub(crate) use bin::{MAGIC as CONTAINER_MAGIC, VERSION as CONTAINER_VERSION};

use std::path::Path;

use anyhow::Result;

/// Convenience: write any [`Serialize`] implementor to a file.
pub fn save<T: Serialize>(value: &T, path: impl AsRef<Path>) -> Result<()> {
    let mut w = BinWriter::create(path)?;
    value.write(&mut w)?;
    w.finish()
}

/// Convenience: read any [`Serialize`] implementor from a file.
pub fn load<T: Serialize>(path: impl AsRef<Path>) -> Result<T> {
    let mut r = BinReader::open(path)?;
    T::read(&mut r)
}

/// Symmetric binary serialization for model/archive types.
pub trait Serialize: Sized {
    fn write(&self, w: &mut BinWriter) -> Result<()>;
    fn read(r: &mut BinReader) -> Result<Self>;
}

impl Serialize for crate::linalg::Mat {
    fn write(&self, w: &mut BinWriter) -> Result<()> {
        w.write_u32(self.rows() as u32)?;
        w.write_u32(self.cols() as u32)?;
        w.write_f64_slice(self.as_slice())
    }

    fn read(r: &mut BinReader) -> Result<Self> {
        let rows = r.read_u32()? as usize;
        let cols = r.read_u32()? as usize;
        let data = r.read_f64_vec(rows * cols)?;
        Ok(crate::linalg::Mat::from_vec(data, rows, cols))
    }
}

impl Serialize for Vec<f64> {
    fn write(&self, w: &mut BinWriter) -> Result<()> {
        w.write_u64(self.len() as u64)?;
        w.write_f64_slice(self)
    }

    fn read(r: &mut BinReader) -> Result<Self> {
        let n = r.read_u64()? as usize;
        r.read_f64_vec(n)
    }
}

impl Serialize for Vec<crate::linalg::Mat> {
    fn write(&self, w: &mut BinWriter) -> Result<()> {
        w.write_u64(self.len() as u64)?;
        for m in self {
            m.write(w)?;
        }
        Ok(())
    }

    fn read(r: &mut BinReader) -> Result<Self> {
        let n = r.read_u64()? as usize;
        (0..n).map(|_| crate::linalg::Mat::read(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn mat_roundtrip() {
        let dir = std::env::temp_dir().join("ivtv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mat.bin");
        let m = Mat::from_fn(5, 3, |i, j| (i * 3 + j) as f64 * 0.5 - 2.0);
        save(&m, &path).unwrap();
        let back: Mat = load(&path).unwrap();
        assert!(back.approx_eq(&m, 0.0));
    }

    #[test]
    fn vec_roundtrip() {
        let dir = std::env::temp_dir().join("ivtv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("vec.bin");
        let v = vec![1.0, -2.5, 3.25];
        save(&v, &path).unwrap();
        let back: Vec<f64> = load(&path).unwrap();
        assert_eq!(back, v);
    }
}
