//! Bench harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warm-up + N timed repetitions, reporting median / mean / p10 / p90.

use std::time::Instant;

/// Summary statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub reps: usize,
}

impl BenchResult {
    /// One aligned human-readable row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} med {:>9} mean {:>9} p10 {:>9} p90 {:>9} (n={})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.p10_s),
            fmt_time(self.p90_s),
            self.reps
        )
    }
}

/// Pretty-print a duration in s/ms/µs.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Time `f` with `warmup` + `reps` runs. The closure's return value is
/// black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(reps > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let result = BenchResult {
        name: name.to_string(),
        median_s: pct(0.5),
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        p10_s: pct(0.1),
        p90_s: pct(0.9),
        reps,
    };
    println!("{}", result.row());
    result
}

/// Optimizer barrier (std::hint::black_box re-export for clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Version stamp every `BENCH_*.json` document carries, so trend
/// tooling can detect a shape change instead of misparsing it.
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// Render one `BENCH_*.json` document: schema version, issue number,
/// then the pre-rendered top-level sections in order. Every bench
/// writer routes through here so the envelope stays uniform.
pub fn bench_json_doc(issue: u32, sections: &[(&str, String)]) -> String {
    let mut body =
        format!("{{\n  \"schema_version\": {BENCH_SCHEMA_VERSION},\n  \"issue\": {issue}");
    for (name, value) in sections {
        body.push_str(&format!(",\n  \"{name}\": {value}"));
    }
    body.push_str("\n}\n");
    body
}

/// Group named per-run fragments into one nested JSON object — the
/// shape of the multi-variant sections (`serving`, `cluster`).
pub fn variants_json(variants: &[(String, String)]) -> String {
    let mut body = String::from("{\n");
    for (i, (name, fragment)) in variants.iter().enumerate() {
        body.push_str(&format!("    \"{name}\": {fragment}"));
        body.push_str(if i + 1 < variants.len() { ",\n" } else { "\n" });
    }
    body.push_str("  }");
    body
}

/// Write a `BENCH_*.json` document (see [`bench_json_doc`]).
pub fn write_bench_json(
    path: impl AsRef<std::path::Path>,
    issue: u32,
    sections: &[(&str, String)],
) -> anyhow::Result<()> {
    std::fs::write(&path, bench_json_doc(issue, sections))
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.as_ref().display()))
}

/// The p50/p95/p99 of one latency series in milliseconds — the shared
/// shape between a live [`crate::metrics::LatencySummary`] and a series
/// parsed back out of an exported snapshot, so drift can be computed
/// over either.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyTriple {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl LatencyTriple {
    pub fn from_summary(s: &crate::metrics::LatencySummary) -> Self {
        Self { p50_ms: s.p50_s * 1e3, p95_ms: s.p95_s * 1e3, p99_ms: s.p99_s * 1e3 }
    }
}

fn drift_pct(old: f64, new: f64) -> f64 {
    if old > 0.0 {
        (new - old) / old * 100.0
    } else {
        0.0
    }
}

/// Percentile drift between two latency series as a JSON fragment:
/// `{"p50_ms": {"old": .., "new": .., "delta_pct": ..}, ...}`. The one
/// comparison shape shared by `stats --diff` and the replayer's
/// `BENCH_10.json` per-stage drift section — ad-hoc per-bench deltas
/// route through here.
pub fn latency_drift_json(old: &LatencyTriple, new: &LatencyTriple) -> String {
    let field = |name: &str, o: f64, n: f64| {
        format!(
            "\"{name}\": {{\"old\": {o:.4}, \"new\": {n:.4}, \"delta_pct\": {:.2}}}",
            drift_pct(o, n)
        )
    };
    format!(
        "{{{}, {}, {}}}",
        field("p50_ms", old.p50_ms, new.p50_ms),
        field("p95_ms", old.p95_ms, new.p95_ms),
        field("p99_ms", old.p99_ms, new.p99_ms),
    )
}

/// One aligned human-readable drift row (the `stats --diff` rendering).
pub fn latency_drift_row(name: &str, old: &LatencyTriple, new: &LatencyTriple) -> String {
    format!(
        "{name:<52} p50 {:>9.3} → {:>9.3} ms ({:+7.2}%)  p95 {:>9.3} → {:>9.3} ms ({:+7.2}%)  \
         p99 {:>9.3} → {:>9.3} ms ({:+7.2}%)",
        old.p50_ms,
        new.p50_ms,
        drift_pct(old.p50_ms, new.p50_ms),
        old.p95_ms,
        new.p95_ms,
        drift_pct(old.p95_ms, new.p95_ms),
        old.p99_ms,
        new.p99_ms,
        drift_pct(old.p99_ms, new.p99_ms),
    )
}

/// One f32-vs-f64 alignment throughput comparison — both paths timed on
/// the same UBM and the same frame block within one harness run, so
/// the speedup is apples-to-apples. Shared by the `speed_report`
/// example and the `serve-bench` CLI command, which both write it out
/// as `BENCH_4.json`.
#[derive(Debug, Clone)]
pub struct AlignPrecisionBench {
    /// UBM components C.
    pub c: usize,
    /// Feature dim F.
    pub f: usize,
    pub top_k: usize,
    /// Frames scored per repetition.
    pub frames: usize,
    pub f64_median_s: f64,
    pub f32_median_s: f64,
}

impl AlignPrecisionBench {
    pub fn frames_per_s_f64(&self) -> f64 {
        self.frames as f64 / self.f64_median_s
    }

    pub fn frames_per_s_f32(&self) -> f64 {
        self.frames as f64 / self.f32_median_s
    }

    /// f64-time / f32-time (>1 ⇒ f32 is faster).
    pub fn f32_speedup(&self) -> f64 {
        self.f64_median_s / self.f32_median_s
    }

    /// The `BENCH_4.json` document (alignment frames/s for both
    /// precisions from the same run).
    pub fn json(&self) -> String {
        let dims = format!(
            "{{\"C\": {}, \"F\": {}, \"top_k\": {}, \"frames\": {}}}",
            self.c, self.f, self.top_k, self.frames,
        );
        let alignment = format!(
            "{{\"f64_s\": {:.6}, \"f32_s\": {:.6}, \"frames_per_s_f64\": {:.2}, \
\"frames_per_s_f32\": {:.2}, \"f32_speedup\": {:.3}}}",
            self.f64_median_s,
            self.f32_median_s,
            self.frames_per_s_f64(),
            self.frames_per_s_f32(),
            self.f32_speedup(),
        );
        bench_json_doc(4, &[("dims", dims), ("alignment", alignment)])
    }
}

/// Time the batched aligner at both precisions over one frame block.
pub fn bench_align_precision(
    diag: &crate::gmm::DiagGmm,
    full: &crate::gmm::FullGmm,
    frames: &crate::linalg::Mat,
    top_k: usize,
    min_post: f64,
    warmup: usize,
    reps: usize,
) -> AlignPrecisionBench {
    use crate::gmm::{AlignPrecision, BatchAligner};
    let f64_r = bench("align/f64-batched", warmup, reps, || {
        BatchAligner::with_precision(diag, full, top_k, min_post, AlignPrecision::F64)
            .align_utterance(frames)
    });
    let f32_r = bench("align/f32-batched", warmup, reps, || {
        BatchAligner::with_precision(diag, full, top_k, min_post, AlignPrecision::F32)
            .align_utterance(frames)
    });
    AlignPrecisionBench {
        c: diag.num_components(),
        f: diag.dim(),
        top_k,
        frames: frames.rows(),
        f64_median_s: f64_r.median_s,
        f32_median_s: f32_r.median_s,
    }
}

/// Write the `BENCH_4.json` precision report.
pub fn write_bench4_json(
    path: impl AsRef<std::path::Path>,
    b: &AlignPrecisionBench,
) -> anyhow::Result<()> {
    std::fs::write(&path, b.json())
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.as_ref().display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let r = bench("noop", 1, 11, || 1 + 1);
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
        assert_eq!(r.reps, 11);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(2.5e-3).ends_with(" ms"));
        assert!(fmt_time(2.5e-6).ends_with(" µs"));
    }

    #[test]
    fn bench4_json_shape() {
        let b = AlignPrecisionBench {
            c: 2048,
            f: 60,
            top_k: 20,
            frames: 1000,
            f64_median_s: 0.5,
            f32_median_s: 0.25,
        };
        assert!((b.f32_speedup() - 2.0).abs() < 1e-12);
        assert!((b.frames_per_s_f32() - 4000.0).abs() < 1e-9);
        let json = b.json();
        assert!(json.contains("\"schema_version\": 1"), "{json}");
        assert!(json.contains("\"issue\": 4"), "{json}");
        assert!(json.contains("\"frames_per_s_f64\": 2000.00"), "{json}");
        assert!(json.contains("\"frames_per_s_f32\": 4000.00"), "{json}");
        assert!(json.contains("\"f32_speedup\": 2.000"), "{json}");

        let dir = std::env::temp_dir().join("ivtv_bench4_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("BENCH_4.json");
        write_bench4_json(&p, &b).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), json);
    }

    #[test]
    fn latency_drift_shapes_and_percentages() {
        let old = LatencyTriple { p50_ms: 2.0, p95_ms: 10.0, p99_ms: 20.0 };
        let new = LatencyTriple { p50_ms: 3.0, p95_ms: 5.0, p99_ms: 20.0 };
        let json = latency_drift_json(&old, &new);
        assert!(json.contains("\"p50_ms\": {\"old\": 2.0000, \"new\": 3.0000, \"delta_pct\": 50.00}"), "{json}");
        assert!(json.contains("\"p95_ms\": {\"old\": 10.0000, \"new\": 5.0000, \"delta_pct\": -50.00}"), "{json}");
        assert!(json.contains("\"delta_pct\": 0.00}"), "{json}");
        // a zero baseline must not divide by zero
        let z = LatencyTriple { p50_ms: 0.0, p95_ms: 0.0, p99_ms: 0.0 };
        assert!(latency_drift_json(&z, &new).contains("\"delta_pct\": 0.00"));
        let row = latency_drift_row("serve_verify_latency_seconds", &old, &new);
        assert!(row.contains("serve_verify_latency_seconds"), "{row}");
        assert!(row.contains("+50.00%"), "{row}");
    }

    #[test]
    fn bench_json_doc_envelope_is_uniform() {
        let doc = bench_json_doc(
            9,
            &[
                ("dims", "{\"C\": 2}".to_string()),
                (
                    "runs",
                    variants_json(&[
                        ("a".to_string(), "{\"x\": 1}".to_string()),
                        ("b".to_string(), "{\"x\": 2}".to_string()),
                    ]),
                ),
            ],
        );
        assert!(doc.starts_with("{\n  \"schema_version\": 1,\n  \"issue\": 9"), "{doc}");
        assert!(doc.contains("\"dims\": {\"C\": 2}"), "{doc}");
        assert!(doc.contains("    \"a\": {\"x\": 1},\n    \"b\": {\"x\": 2}\n  }"), "{doc}");
        assert!(doc.ends_with("\n}\n"), "{doc}");
    }
}
