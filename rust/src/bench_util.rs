//! Bench harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warm-up + N timed repetitions, reporting median / mean / p10 / p90.

use std::time::Instant;

/// Summary statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub reps: usize,
}

impl BenchResult {
    /// One aligned human-readable row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} med {:>9} mean {:>9} p10 {:>9} p90 {:>9} (n={})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.p10_s),
            fmt_time(self.p90_s),
            self.reps
        )
    }
}

/// Pretty-print a duration in s/ms/µs.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Time `f` with `warmup` + `reps` runs. The closure's return value is
/// black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> BenchResult {
    assert!(reps > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| times[((times.len() - 1) as f64 * p) as usize];
    let result = BenchResult {
        name: name.to_string(),
        median_s: pct(0.5),
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        p10_s: pct(0.1),
        p90_s: pct(0.9),
        reps,
    };
    println!("{}", result.row());
    result
}

/// Optimizer barrier (std::hint::black_box re-export for clarity).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let r = bench("noop", 1, 11, || 1 + 1);
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
        assert_eq!(r.reps, 11);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).ends_with(" s"));
        assert!(fmt_time(2.5e-3).ends_with(" ms"));
        assert!(fmt_time(2.5e-6).ends_with(" µs"));
    }
}
