//! Config system: a TOML-subset parser plus the typed experiment config.
//!
//! No serde/toml crates are available offline, so `parse_toml` handles
//! the subset the configs use: `[section]` headers, `key = value` with
//! string / integer / float / boolean scalars, `#` comments.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::gmm::AlignPrecision;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str) -> Result<Value> {
        let s = raw.trim();
        if let Some(stripped) = s.strip_prefix('"') {
            let inner = stripped.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string: {s}"))?;
            return Ok(Value::Str(inner.to_string()));
        }
        match s {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        bail!("cannot parse value `{s}`")
    }
}

/// `section.key → value` map from a TOML-subset document.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    values: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // a '#' inside a quoted string would break this; configs
                // don't use '#' in strings.
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| anyhow!("line {}: bad section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() { k.trim().to_string() } else { format!("{section}.{}", k.trim()) };
            values.insert(key, Value::parse(v).with_context(|| format!("line {}", lineno + 1))?);
        }
        Ok(Self { values })
    }

    /// Load from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read config {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(other) => bail!("{key}: expected number, got {other:?}"),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(Value::Int(i)) if *i >= 0 => Ok(*i as usize),
            Some(other) => bail!("{key}: expected non-negative integer, got {other:?}"),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key) {
            None => Ok(default),
            Some(Value::Bool(b)) => Ok(*b),
            Some(other) => bail!("{key}: expected bool, got {other:?}"),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.values.get(key) {
            None => Ok(default.to_string()),
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(other) => bail!("{key}: expected string, got {other:?}"),
        }
    }

    /// Whether `key` appears in the document — the "was this overridden
    /// at all" probe behind optional per-replica cluster overrides.
    pub fn has(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }

    /// All keys starting with `prefix` — section scans for validating
    /// that dynamic subsections (e.g. `[cluster.replicaN]`) actually
    /// land on something instead of being silently ignored.
    pub fn keys_with_prefix<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> {
        self.values.keys().map(String::as_str).filter(move |k| k.starts_with(prefix))
    }
}

/// Synthetic-corpus parameters (DESIGN.md substitution table: stands in
/// for VoxCeleb + the Kaldi MFCC front-end).
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_train_speakers: usize,
    pub utts_per_train_speaker: usize,
    pub n_eval_speakers: usize,
    pub utts_per_eval_speaker: usize,
    pub min_frames: usize,
    pub max_frames: usize,
    /// Base feature dim before deltas (final dim = 3 × this).
    pub base_dim: usize,
    /// Ground-truth world GMM components.
    pub true_components: usize,
    /// Rank / scale of the ground-truth speaker subspace.
    pub speaker_rank: usize,
    pub speaker_scale: f64,
    /// Rank / scale of the ground-truth channel subspace.
    pub channel_rank: usize,
    pub channel_scale: f64,
    /// Sticky-Markov stay probability (gives deltas temporal structure).
    pub stay_prob: f64,
    /// Fraction of leading/trailing silence frames (exercises VAD).
    pub silence_frac: f64,
    pub seed: u64,
}

/// UBM parameters (paper: 2048 full-cov components — scaled here).
#[derive(Debug, Clone)]
pub struct UbmConfig {
    pub components: usize,
    pub diag_em_iters: usize,
    pub full_em_iters: usize,
    /// Frames subsampled for UBM training.
    pub train_frames: usize,
    pub var_floor: f64,
}

/// Total-variability model parameters.
#[derive(Debug, Clone)]
pub struct TvmConfig {
    /// i-vector dimension (paper: 400).
    pub rank: usize,
    /// EM iterations (paper explores up to 200; optimum ≈ 22).
    pub iters: usize,
    /// Top-K Gaussians kept per frame in alignment (paper: 20).
    pub top_k: usize,
    /// Posterior pruning threshold (paper: 0.025).
    pub min_post: f64,
    /// Prior offset for the augmented formulation (Kaldi: 100).
    pub prior_offset: f64,
    /// Utterances used for extractor training (paper: 100k longest).
    pub train_utts: usize,
    /// Device batch size (utterances per E-step dispatch).
    pub batch_utts: usize,
    /// Frames per alignment dispatch.
    pub batch_frames: usize,
}

/// Frame-alignment compute parameters (`[align]`).
#[derive(Debug, Clone)]
pub struct AlignConfig {
    /// Scalar width of the diagonal-scoring GEMM + top-K selection.
    /// `"f64"` (default) is bit-stable against the scalar oracle;
    /// `"f32"` roughly doubles alignment throughput and mirrors the
    /// device runtime's precision. Log-sum-exp, posterior
    /// normalization, and all Baum-Welch/E-step accumulation stay f64
    /// either way. Applies to both the trainer's alignment passes and
    /// the serving engine (mirrored into [`ServeConfig::precision`]).
    pub precision: AlignPrecision,
}

/// Backend parameters.
#[derive(Debug, Clone)]
pub struct BackendConfig {
    /// LDA output dim (paper: 400 → 200; scaled).
    pub lda_dim: usize,
    pub plda_iters: usize,
}

/// Trial-list parameters (paper: VoxCeleb1 protocol, 37 720 trials,
/// equal target/non-target).
#[derive(Debug, Clone)]
pub struct TrialConfig {
    pub n_trials: usize,
    pub seed: u64,
}

/// Online-serving parameters ([`crate::serve`]): micro-batcher shape,
/// admission-control deadlines, and registry sharding.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max requests coalesced into one E-step dispatch (flush-on-size).
    pub batch_utts: usize,
    /// Micro-batch flush deadline in microseconds (flush-on-deadline):
    /// the max time the first request in a batch waits for co-riders.
    pub flush_us: u64,
    /// E-step worker threads draining the micro-batch queue.
    pub workers: usize,
    /// Lock shards of the speaker registry.
    pub registry_shards: usize,
    /// Bound on queued (admitted, not yet dispatched) requests.
    pub queue_cap: usize,
    /// Admission deadline in milliseconds: how long a request may wait
    /// for queue space before it is load-shed with a typed
    /// `Overloaded` error instead of blocking its thread.
    pub submit_timeout_ms: u64,
    /// End-to-end request deadline in milliseconds: how long a request
    /// may wait for its batched response before failing with a typed
    /// `Timeout` error (bounds the damage of a stalled worker).
    pub request_timeout_ms: u64,
    /// Aligner-scratch buffers retained in the per-model checkout pool
    /// (~2 MB each at paper dims; 0 disables pooling). Size it to the
    /// expected number of concurrently-aligning request threads.
    pub scratch_pool: usize,
    /// Alignment scoring precision for request threads. Defaults to
    /// the `[align] precision` knob (one knob covers trainer and
    /// serving); an explicit `[serve] precision` key overrides it for
    /// serving only — e.g. f64 training artifacts served at f32.
    pub precision: AlignPrecision,
    /// Streaming-session knobs (`[session]` section; rides along so a
    /// cluster replica inherits them through `replica_serve_cfg`).
    pub session: SessionConfig,
}

/// Streaming-session parameters (`[session]`,
/// [`crate::serve::session`]): table capacity, idle eviction, and the
/// early-exit decision thresholds.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionConfig {
    /// Bound on live sessions per engine; an open past it is shed with
    /// a typed `SessionLimit` error (admission control for the state
    /// the table pins: partial stats + a model snapshot per session).
    pub max_sessions: usize,
    /// Idle deadline in milliseconds: a session with no feed/score
    /// activity for this long is reclaimed by the eviction sweep and
    /// subsequent ops fail typed (`SessionExpired`).
    pub idle_ms: u64,
    /// Lock shards of the session table.
    pub shards: usize,
    /// Early exit never fires before this many accumulated frames —
    /// partial-stat scores on a handful of frames are noise, not
    /// evidence.
    pub min_frames: usize,
    /// Early-accept threshold: a feed whose interim score reaches this
    /// finalizes the session immediately (`None` disables).
    pub accept_score: Option<f64>,
    /// Early-reject threshold: a feed whose interim score falls at or
    /// below this finalizes the session immediately (`None` disables).
    pub reject_score: Option<f64>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            max_sessions: 1024,
            idle_ms: 30_000,
            shards: 16,
            min_frames: 60,
            accept_score: None,
            reject_score: None,
        }
    }
}

/// WAL fsync policy of the durable speaker registry (`[registry] sync`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalSync {
    /// Fsync after every record — an acknowledged mutation is on stable
    /// storage before the caller sees `Ok`.
    Always,
    /// Fsync after every N records — higher enrollment throughput, but
    /// a crash may lose up to N-1 acknowledged-but-unsynced mutations.
    EveryN(u64),
}

impl WalSync {
    /// Parse the config/CLI spelling: `"always"`, or an integer ≥ 1
    /// (where 1 is just `always`).
    pub fn parse(s: &str) -> Result<Self> {
        if s == "always" {
            return Ok(Self::Always);
        }
        match s.parse::<u64>() {
            Ok(0) => bail!("sync interval must be >= 1 (or \"always\"), got 0"),
            Ok(1) => Ok(Self::Always),
            Ok(n) => Ok(Self::EveryN(n)),
            Err(_) => bail!("sync must be \"always\" or an integer >= 1, got `{s}`"),
        }
    }
}

impl std::fmt::Display for WalSync {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Always => f.write_str("always"),
            Self::EveryN(n) => write!(f, "every-{n}"),
        }
    }
}

/// Durable speaker-registry parameters (`[registry]`,
/// [`crate::serve::registry`]): storage location, WAL policy, and the
/// compaction threshold.
#[derive(Debug, Clone)]
pub struct RegistryConfig {
    /// Directory holding `registry.wal` + `registry.snap`. `None`
    /// (default) keeps the registry volatile — the pre-durability
    /// behaviour.
    pub path: Option<String>,
    /// Write-ahead-log mutations (`false` = snapshot-only durability:
    /// mutations after the last compaction die with the process).
    pub wal: bool,
    /// WAL fsync policy.
    pub sync: WalSync,
    /// Compact the WAL into a snapshot after this many records
    /// (0 = never compact automatically).
    pub compact_every: u64,
}

/// Observability parameters (`[obs]`, [`crate::obs`]): master switch,
/// slow-trace threshold, and the trace-ring capacity.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Master switch. `false` turns the registry inert: counters stay
    /// at zero, spans cost a clock read, traces are never minted.
    pub enabled: bool,
    /// Completed requests whose end-to-end latency reaches this many
    /// milliseconds land in the slow-trace ring (0 = keep every
    /// completed trace, the default — the ring then holds the most
    /// recent `trace_ring` requests).
    pub trace_threshold_ms: f64,
    /// Slow-trace ring capacity (completed traces retained).
    pub trace_ring: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self { enabled: true, trace_threshold_ms: 0.0, trace_ring: 64 }
    }
}

/// How the cluster dispatcher picks a replica for each request
/// (`[cluster] route`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through admitting replicas — fair under uniform request
    /// cost, oblivious to backlog.
    RoundRobin,
    /// Pick the replica with the smallest load (dispatcher in-flight
    /// counter + live micro-batch queue depth) — steers around a slow
    /// or saturated replica before admission control has to shed.
    LeastDepth,
}

impl RoutePolicy {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "round_robin" => Ok(Self::RoundRobin),
            "least_depth" => Ok(Self::LeastDepth),
            other => bail!("route must be \"round_robin\" or \"least_depth\", got `{other}`"),
        }
    }

    /// The config/CLI spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::RoundRobin => "round_robin",
            Self::LeastDepth => "least_depth",
        }
    }
}

impl std::fmt::Display for RoutePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which finished requests the flight recorder samples into the
/// capture log (`[capture] policy`, [`crate::serve::capture`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplePolicy {
    /// Every request — the replayable-corpus setting: only a complete
    /// capture can reproduce registry state (enrollment counts) on
    /// replay.
    All,
    /// One in N (deterministic modulo over an admission counter).
    Rate(u32),
    /// Only requests at least as slow as the obs layer's
    /// `trace_threshold_ms` — the same knob that feeds the slow-trace
    /// ring feeds the corpus.
    SlowOnly,
    /// Only requests whose outcome is not `ok` (shed / timeout /
    /// failed) — a black box that records incidents.
    ErrorsOnly,
}

impl SamplePolicy {
    /// Parse the config/CLI spelling: `"all"`, `"slow_only"`,
    /// `"errors_only"`, or `"rate N"` / `"rate 1/N"` (one in N).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "all" => return Ok(Self::All),
            "slow_only" => return Ok(Self::SlowOnly),
            "errors_only" => return Ok(Self::ErrorsOnly),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("rate") {
            let rest = rest.trim().trim_start_matches("1/");
            if let Ok(n) = rest.parse::<u32>() {
                if n >= 1 {
                    return Ok(Self::Rate(n));
                }
            }
        }
        bail!(
            "capture policy must be \"all\", \"slow_only\", \"errors_only\", \
             or \"rate N\" (one in N, N >= 1), got `{s}`"
        )
    }

    /// The config/CLI spelling (round-trips through [`Self::parse`]).
    pub fn as_str(&self) -> String {
        match self {
            Self::All => "all".into(),
            Self::Rate(n) => format!("rate 1/{n}"),
            Self::SlowOnly => "slow_only".into(),
            Self::ErrorsOnly => "errors_only".into(),
        }
    }
}

impl std::fmt::Display for SamplePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.as_str())
    }
}

/// Flight-recorder parameters (`[capture]`,
/// [`crate::serve::capture`]): the sampling policy and the bounds of
/// the never-blocking background writer. The capture *destination* is
/// per-run (`--capture-out`), not config; the `slow_only` cutoff rides
/// `[obs] trace_threshold_ms` and the recorded deadline rides
/// `[serve] request_timeout_ms`.
#[derive(Debug, Clone)]
pub struct CaptureConfig {
    /// Master switch: `false` makes `--capture-out` a typed refusal
    /// instead of a silently empty corpus.
    pub enabled: bool,
    /// Which finished requests enter the corpus.
    pub policy: SamplePolicy,
    /// Bounded channel depth between request threads and the capture
    /// writer — overflow drops records (counted), never blocks.
    pub queue: usize,
    /// Fsync the capture log every this many records (and at close).
    pub sync_every: u64,
}

impl Default for CaptureConfig {
    fn default() -> Self {
        Self { enabled: true, policy: SamplePolicy::All, queue: 1024, sync_every: 64 }
    }
}

/// Per-replica deviations from the shared `[serve]` engine shape
/// (`[cluster.replicaN]` subsections) — how heterogeneous bundles serve
/// side by side: e.g. replica 0 at f64 for bit-stable scoring, replica
/// 1 at f32 for throughput (and, once the accel serving path lands, a
/// CPU replica next to a device one). Unset fields inherit `[serve]`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaOverride {
    /// Alignment scoring precision for this replica only.
    pub precision: Option<AlignPrecision>,
    /// E-step worker threads for this replica only.
    pub workers: Option<usize>,
    /// Micro-batch size for this replica only.
    pub batch_utts: Option<usize>,
}

impl ReplicaOverride {
    /// True when any field deviates from the shared `[serve]` shape.
    pub fn is_override(&self) -> bool {
        *self != Self::default()
    }
}

/// Replica health supervision knobs (`[cluster.health]`,
/// [`crate::serve::cluster::health`]): the sliding error-budget window
/// and circuit-breaker timings of the self-healing supervisor tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HealthConfig {
    /// Master switch; `false` makes the tracker inert (every replica
    /// reports healthy and the supervisor never quarantines, rebuilds,
    /// or probes).
    pub enabled: bool,
    /// Sliding error-budget window, in milliseconds.
    pub window_ms: u64,
    /// Hard faults (request timeouts + worker panics + hard errors)
    /// inside the window that quarantine a replica; half the budget
    /// only degrades it.
    pub fault_budget: u64,
    /// Admission sheds inside the window that mark a replica degraded.
    /// Sheds alone never quarantine — a saturated replica is busy, not
    /// broken.
    pub shed_budget: u64,
    /// Circuit-breaker cooldown after a quarantined engine is rebuilt,
    /// in milliseconds, before the half-open canary probe runs.
    pub cooldown_ms: u64,
    /// Frames in the canary probe utterance the half-open state sends.
    pub probe_frames: usize,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            window_ms: 2_000,
            fault_budget: 5,
            shed_budget: 256,
            cooldown_ms: 250,
            probe_frames: 16,
        }
    }
}

/// Multi-engine cluster parameters (`[cluster]`,
/// [`crate::serve::cluster`]): replica count, routing policy, shed
/// failover budget, and the per-replica drain bound of a rolling swap.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Engine replicas behind the dispatcher (each with its own worker
    /// pool and micro-batch queue; all sharing one speaker registry).
    pub replicas: usize,
    /// Routing policy for new requests.
    pub route: RoutePolicy,
    /// Max retries on *other* replicas after a shed (`Overloaded`) or
    /// draining (`ShuttingDown`) rejection, all within the original
    /// request deadline. 0 disables failover.
    pub max_failovers: usize,
    /// Per-replica drain bound during a rolling swap, in milliseconds:
    /// how long the swap waits for a retired engine's workers to finish
    /// the queued jobs and exit before moving to the next replica.
    pub drain_timeout_ms: u64,
    /// Per-replica overrides, indexed by replica id; missing/default
    /// entries inherit `[serve]` unchanged.
    pub overrides: Vec<ReplicaOverride>,
    /// Replica health supervision (`[cluster.health]`).
    pub health: HealthConfig,
}

impl ClusterConfig {
    /// The effective `[serve]` shape of replica `i`: the shared base
    /// with this replica's overrides applied.
    pub fn replica_serve_cfg(&self, base: &ServeConfig, i: usize) -> ServeConfig {
        let mut cfg = base.clone();
        if let Some(o) = self.overrides.get(i) {
            if let Some(p) = o.precision {
                cfg.precision = p;
            }
            if let Some(w) = o.workers {
                cfg.workers = w;
            }
            if let Some(b) = o.batch_utts {
                cfg.batch_utts = b;
            }
        }
        cfg
    }
}

/// Full experiment config.
#[derive(Debug, Clone)]
pub struct Config {
    pub corpus: CorpusConfig,
    pub ubm: UbmConfig,
    pub tvm: TvmConfig,
    pub align: AlignConfig,
    pub backend: BackendConfig,
    pub trials: TrialConfig,
    pub serve: ServeConfig,
    pub cluster: ClusterConfig,
    pub registry: RegistryConfig,
    pub obs: ObsConfig,
    pub capture: CaptureConfig,
}

impl Config {
    /// Built-in defaults (the scaled-down VoxCeleb recipe of DESIGN.md).
    pub fn default_scaled() -> Self {
        Self {
            corpus: CorpusConfig {
                n_train_speakers: 150,
                utts_per_train_speaker: 12,
                n_eval_speakers: 40,
                utts_per_eval_speaker: 8,
                // ≥ ~250 speech frames/utt keeps per-component stats
                // informative at C = 64 (validated: shorter utterances
                // drown the speaker offsets in estimation noise)
                min_frames: 250,
                max_frames: 450,
                base_dim: 8,
                true_components: 64,
                speaker_rank: 24,
                speaker_scale: 1.0,
                channel_rank: 12,
                channel_scale: 0.25,
                stay_prob: 0.9,
                silence_frac: 0.12,
                seed: 20190915,
            },
            ubm: UbmConfig {
                components: 64,
                diag_em_iters: 8,
                full_em_iters: 4,
                train_frames: 100_000,
                var_floor: 1e-3,
            },
            tvm: TvmConfig {
                rank: 64,
                iters: 22,
                top_k: 20,
                min_post: 0.025,
                prior_offset: 100.0,
                train_utts: usize::MAX,
                batch_utts: 64,
                batch_frames: 4096,
            },
            align: AlignConfig { precision: AlignPrecision::F64 },
            backend: BackendConfig { lda_dim: 32, plda_iters: 8 },
            trials: TrialConfig { n_trials: 8000, seed: 7 },
            serve: ServeConfig {
                batch_utts: 32,
                flush_us: 2000,
                workers: 2,
                registry_shards: 16,
                queue_cap: 1024,
                submit_timeout_ms: 250,
                request_timeout_ms: 10_000,
                scratch_pool: 8,
                precision: AlignPrecision::F64,
                session: SessionConfig::default(),
            },
            cluster: ClusterConfig {
                replicas: 2,
                route: RoutePolicy::LeastDepth,
                max_failovers: 2,
                drain_timeout_ms: 5_000,
                overrides: Vec::new(),
                health: HealthConfig::default(),
            },
            registry: RegistryConfig {
                path: None,
                wal: true,
                sync: WalSync::Always,
                compact_every: 10_000,
            },
            obs: ObsConfig::default(),
            capture: CaptureConfig::default(),
        }
    }

    /// Defaults overridden by a TOML-subset file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let doc = Doc::load(path)?;
        Self::from_doc(&doc)
    }

    /// Defaults overridden by a parsed document.
    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let d = Self::default_scaled();
        // one knob, two consumers: the trainer reads `align.precision`,
        // the serving engine its ServeConfig mirror — which an explicit
        // `serve.precision` key may override for serving alone
        let precision = AlignPrecision::parse(
            &doc.get_str("align.precision", d.align.precision.as_str())?,
        )
        .context("align.precision")?;
        let serve_precision =
            AlignPrecision::parse(&doc.get_str("serve.precision", precision.as_str())?)
                .context("serve.precision")?;
        // `[cluster]` basics plus optional `[cluster.replicaN]`
        // subsections (the TOML-subset parser flattens those to
        // `cluster.replicaN.key` entries)
        let replicas = doc.get_usize("cluster.replicas", d.cluster.replicas)?.max(1);
        let route = RoutePolicy::parse(&doc.get_str("cluster.route", d.cluster.route.as_str())?)
            .context("cluster.route")?;
        let mut overrides = Vec::with_capacity(replicas);
        for i in 0..replicas {
            let key = format!("cluster.replica{i}.precision");
            let precision = if doc.has(&key) {
                Some(AlignPrecision::parse(&doc.get_str(&key, "")?).context(key)?)
            } else {
                None
            };
            let key = format!("cluster.replica{i}.workers");
            let workers = if doc.has(&key) { Some(doc.get_usize(&key, 0)?) } else { None };
            let key = format!("cluster.replica{i}.batch_utts");
            let batch_utts = if doc.has(&key) { Some(doc.get_usize(&key, 0)?) } else { None };
            overrides.push(ReplicaOverride { precision, workers, batch_utts });
        }
        // a `[cluster.replicaN]` section outside 0..replicas would
        // otherwise parse cleanly and be silently ignored — the classic
        // 1-based-vs-0-based mistake must be an error, not dead config
        for key in doc.keys_with_prefix("cluster.replica") {
            let rest = &key["cluster.replica".len()..];
            // `cluster.replicas` (the count) shares the prefix; only
            // `cluster.replicaN.field` keys are per-replica overrides
            let Some((idx, field)) = rest.split_once('.') else { continue };
            let i: usize = idx.parse().map_err(|_| {
                anyhow!("config section `[cluster.replica{idx}]`: replica id must be a number")
            })?;
            // the override reader probes the canonical spelling only, so
            // a non-canonical id (`replica01`, `replica+1`) would parse
            // here yet never be read — reject it instead of dropping it
            if idx != i.to_string() {
                bail!(
                    "config section `[cluster.replica{idx}]`: write the replica id as \
                     `replica{i}` (no leading zeros or signs)"
                );
            }
            if i >= replicas {
                bail!(
                    "config section `[cluster.replica{i}]` is outside the configured \
                     replica range 0..{replicas} (ids are 0-based; raise [cluster] replicas \
                     or renumber the section)"
                );
            }
            if !matches!(field, "precision" | "workers" | "batch_utts") {
                bail!(
                    "config key `{key}`: unknown per-replica override `{field}` \
                     (supported: precision, workers, batch_utts)"
                );
            }
        }
        // `[cluster.health]` supervision knobs, same typo discipline as
        // the other sections
        for key in doc.keys_with_prefix("cluster.health.") {
            let field = &key["cluster.health.".len()..];
            if !matches!(
                field,
                "enabled" | "window_ms" | "fault_budget" | "shed_budget" | "cooldown_ms"
                    | "probe_frames"
            ) {
                bail!(
                    "config key `{key}`: unknown [cluster.health] field `{field}` (supported: \
                     enabled, window_ms, fault_budget, shed_budget, cooldown_ms, probe_frames)"
                );
            }
        }
        let dh = &d.cluster.health;
        let health = HealthConfig {
            enabled: doc.get_bool("cluster.health.enabled", dh.enabled)?,
            window_ms: doc.get_usize("cluster.health.window_ms", dh.window_ms as usize)? as u64,
            fault_budget: doc
                .get_usize("cluster.health.fault_budget", dh.fault_budget as usize)?
                as u64,
            shed_budget: doc.get_usize("cluster.health.shed_budget", dh.shed_budget as usize)?
                as u64,
            cooldown_ms: doc.get_usize("cluster.health.cooldown_ms", dh.cooldown_ms as usize)?
                as u64,
            probe_frames: doc.get_usize("cluster.health.probe_frames", dh.probe_frames)?,
        };
        // `[registry]` durability knobs. `sync` accepts either spelling
        // the TOML-subset parser produces: a bare integer (every-N) or
        // the string "always".
        let registry_sync = if doc.has("registry.sync") {
            match doc.get_usize("registry.sync", 0) {
                Ok(n) => WalSync::parse(&n.to_string()).context("registry.sync")?,
                Err(_) => WalSync::parse(&doc.get_str("registry.sync", "")?)
                    .context("registry.sync")?,
            }
        } else {
            d.registry.sync
        };
        // a typo'd `[registry]` key would silently fall back to the
        // default — surface it like the per-replica overrides above
        for key in doc.keys_with_prefix("registry.") {
            let field = &key["registry.".len()..];
            if !matches!(field, "path" | "wal" | "sync" | "compact_every") {
                bail!(
                    "config key `{key}`: unknown [registry] field `{field}` \
                     (supported: path, wal, sync, compact_every)"
                );
            }
        }
        // `[obs]` observability knobs, same typo discipline
        for key in doc.keys_with_prefix("obs.") {
            let field = &key["obs.".len()..];
            if !matches!(field, "enabled" | "trace_threshold_ms" | "trace_ring") {
                bail!(
                    "config key `{key}`: unknown [obs] field `{field}` \
                     (supported: enabled, trace_threshold_ms, trace_ring)"
                );
            }
        }
        let obs = ObsConfig {
            enabled: doc.get_bool("obs.enabled", d.obs.enabled)?,
            trace_threshold_ms: doc.get_f64("obs.trace_threshold_ms", d.obs.trace_threshold_ms)?,
            trace_ring: doc.get_usize("obs.trace_ring", d.obs.trace_ring)?,
        };
        // `[capture]` flight-recorder knobs, same typo discipline
        for key in doc.keys_with_prefix("capture.") {
            let field = &key["capture.".len()..];
            if !matches!(field, "enabled" | "policy" | "queue" | "sync_every") {
                bail!(
                    "config key `{key}`: unknown [capture] field `{field}` \
                     (supported: enabled, policy, queue, sync_every)"
                );
            }
        }
        let capture = CaptureConfig {
            enabled: doc.get_bool("capture.enabled", d.capture.enabled)?,
            policy: SamplePolicy::parse(
                &doc.get_str("capture.policy", &d.capture.policy.as_str())?,
            )
            .context("capture.policy")?,
            queue: doc.get_usize("capture.queue", d.capture.queue)?.max(1),
            sync_every: doc.get_usize("capture.sync_every", d.capture.sync_every as usize)?.max(1)
                as u64,
        };
        // `[session]` streaming knobs, same typo discipline
        for key in doc.keys_with_prefix("session.") {
            let field = &key["session.".len()..];
            if !matches!(
                field,
                "max_sessions" | "idle_ms" | "shards" | "min_frames" | "accept_score"
                    | "reject_score"
            ) {
                bail!(
                    "config key `{key}`: unknown [session] field `{field}` (supported: \
                     max_sessions, idle_ms, shards, min_frames, accept_score, reject_score)"
                );
            }
        }
        let ds = &d.serve.session;
        let session = SessionConfig {
            max_sessions: doc.get_usize("session.max_sessions", ds.max_sessions)?.max(1),
            idle_ms: doc.get_usize("session.idle_ms", ds.idle_ms as usize)? as u64,
            shards: doc.get_usize("session.shards", ds.shards)?.max(1),
            min_frames: doc.get_usize("session.min_frames", ds.min_frames)?,
            // absent = disabled: a threshold has no meaningful default
            accept_score: if doc.has("session.accept_score") {
                Some(doc.get_f64("session.accept_score", 0.0)?)
            } else {
                ds.accept_score
            },
            reject_score: if doc.has("session.reject_score") {
                Some(doc.get_f64("session.reject_score", 0.0)?)
            } else {
                ds.reject_score
            },
        };
        let registry_path = doc.get_str("registry.path", "")?;
        let registry = RegistryConfig {
            path: if registry_path.is_empty() { None } else { Some(registry_path) },
            wal: doc.get_bool("registry.wal", d.registry.wal)?,
            sync: registry_sync,
            compact_every: doc
                .get_usize("registry.compact_every", d.registry.compact_every as usize)?
                as u64,
        };
        Ok(Self {
            corpus: CorpusConfig {
                n_train_speakers: doc.get_usize("corpus.n_train_speakers", d.corpus.n_train_speakers)?,
                utts_per_train_speaker: doc.get_usize("corpus.utts_per_train_speaker", d.corpus.utts_per_train_speaker)?,
                n_eval_speakers: doc.get_usize("corpus.n_eval_speakers", d.corpus.n_eval_speakers)?,
                utts_per_eval_speaker: doc.get_usize("corpus.utts_per_eval_speaker", d.corpus.utts_per_eval_speaker)?,
                min_frames: doc.get_usize("corpus.min_frames", d.corpus.min_frames)?,
                max_frames: doc.get_usize("corpus.max_frames", d.corpus.max_frames)?,
                base_dim: doc.get_usize("corpus.base_dim", d.corpus.base_dim)?,
                true_components: doc.get_usize("corpus.true_components", d.corpus.true_components)?,
                speaker_rank: doc.get_usize("corpus.speaker_rank", d.corpus.speaker_rank)?,
                speaker_scale: doc.get_f64("corpus.speaker_scale", d.corpus.speaker_scale)?,
                channel_rank: doc.get_usize("corpus.channel_rank", d.corpus.channel_rank)?,
                channel_scale: doc.get_f64("corpus.channel_scale", d.corpus.channel_scale)?,
                stay_prob: doc.get_f64("corpus.stay_prob", d.corpus.stay_prob)?,
                silence_frac: doc.get_f64("corpus.silence_frac", d.corpus.silence_frac)?,
                seed: doc.get_usize("corpus.seed", d.corpus.seed as usize)? as u64,
            },
            ubm: UbmConfig {
                components: doc.get_usize("ubm.components", d.ubm.components)?,
                diag_em_iters: doc.get_usize("ubm.diag_em_iters", d.ubm.diag_em_iters)?,
                full_em_iters: doc.get_usize("ubm.full_em_iters", d.ubm.full_em_iters)?,
                train_frames: doc.get_usize("ubm.train_frames", d.ubm.train_frames)?,
                var_floor: doc.get_f64("ubm.var_floor", d.ubm.var_floor)?,
            },
            tvm: TvmConfig {
                rank: doc.get_usize("tvm.rank", d.tvm.rank)?,
                iters: doc.get_usize("tvm.iters", d.tvm.iters)?,
                top_k: doc.get_usize("tvm.top_k", d.tvm.top_k)?,
                min_post: doc.get_f64("tvm.min_post", d.tvm.min_post)?,
                prior_offset: doc.get_f64("tvm.prior_offset", d.tvm.prior_offset)?,
                train_utts: doc.get_usize("tvm.train_utts", d.tvm.train_utts)?,
                batch_utts: doc.get_usize("tvm.batch_utts", d.tvm.batch_utts)?,
                batch_frames: doc.get_usize("tvm.batch_frames", d.tvm.batch_frames)?,
            },
            align: AlignConfig { precision },
            backend: BackendConfig {
                lda_dim: doc.get_usize("backend.lda_dim", d.backend.lda_dim)?,
                plda_iters: doc.get_usize("backend.plda_iters", d.backend.plda_iters)?,
            },
            trials: TrialConfig {
                n_trials: doc.get_usize("trials.n_trials", d.trials.n_trials)?,
                seed: doc.get_usize("trials.seed", d.trials.seed as usize)? as u64,
            },
            serve: ServeConfig {
                batch_utts: doc.get_usize("serve.batch_utts", d.serve.batch_utts)?,
                flush_us: doc.get_usize("serve.flush_us", d.serve.flush_us as usize)? as u64,
                workers: doc.get_usize("serve.workers", d.serve.workers)?,
                registry_shards: doc
                    .get_usize("serve.registry_shards", d.serve.registry_shards)?,
                queue_cap: doc.get_usize("serve.queue_cap", d.serve.queue_cap)?,
                submit_timeout_ms: doc
                    .get_usize("serve.submit_timeout_ms", d.serve.submit_timeout_ms as usize)?
                    as u64,
                request_timeout_ms: doc
                    .get_usize("serve.request_timeout_ms", d.serve.request_timeout_ms as usize)?
                    as u64,
                scratch_pool: doc.get_usize("serve.scratch_pool", d.serve.scratch_pool)?,
                precision: serve_precision,
                session,
            },
            cluster: ClusterConfig {
                replicas,
                route,
                max_failovers: doc.get_usize("cluster.max_failovers", d.cluster.max_failovers)?,
                drain_timeout_ms: doc
                    .get_usize("cluster.drain_timeout_ms", d.cluster.drain_timeout_ms as usize)?
                    as u64,
                overrides,
                health,
            },
            registry,
            obs,
            capture,
        })
    }

    /// Feature dimension after deltas.
    pub fn feat_dim(&self) -> usize {
        3 * self.corpus.base_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            "# comment\n\
             top = 1\n\
             [tvm]\n\
             rank = 32   # inline comment\n\
             min_post = 0.05\n\
             [corpus]\n\
             seed = 99\n\
             name = \"vox-scaled\"\n\
             flag = true\n",
        )
        .unwrap();
        assert_eq!(doc.get_usize("top", 0).unwrap(), 1);
        assert_eq!(doc.get_usize("tvm.rank", 0).unwrap(), 32);
        assert_eq!(doc.get_f64("tvm.min_post", 0.0).unwrap(), 0.05);
        assert_eq!(doc.get_str("corpus.name", "").unwrap(), "vox-scaled");
        assert!(doc.get_bool("corpus.flag", false).unwrap());
    }

    #[test]
    fn defaults_survive_partial_file() {
        let doc = Doc::parse("[tvm]\nrank = 16\n").unwrap();
        let cfg = Config::from_doc(&doc).unwrap();
        assert_eq!(cfg.tvm.rank, 16);
        assert_eq!(cfg.tvm.top_k, 20); // default preserved
        assert_eq!(cfg.feat_dim(), 24);
        assert_eq!(cfg.serve.batch_utts, 32); // serve defaults preserved
        assert_eq!(cfg.cluster.health, HealthConfig::default());
    }

    #[test]
    fn cluster_health_section_overrides() {
        let doc = Doc::parse(
            "[cluster.health]\nenabled = false\nwindow_ms = 500\nfault_budget = 9\n\
             shed_budget = 32\ncooldown_ms = 75\nprobe_frames = 8\n",
        )
        .unwrap();
        let h = Config::from_doc(&doc).unwrap().cluster.health;
        assert!(!h.enabled);
        assert_eq!(h.window_ms, 500);
        assert_eq!(h.fault_budget, 9);
        assert_eq!(h.shed_budget, 32);
        assert_eq!(h.cooldown_ms, 75);
        assert_eq!(h.probe_frames, 8);
    }

    #[test]
    fn cluster_health_unknown_key_is_an_error() {
        let doc = Doc::parse("[cluster.health]\nwindow = 500\n").unwrap();
        let err = Config::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("cluster.health.window"), "{err}");
        assert!(err.contains("window_ms"), "{err}");
    }

    #[test]
    fn serve_section_overrides() {
        let doc = Doc::parse(
            "[serve]\nbatch_utts = 8\nflush_us = 500\nworkers = 4\n\
             registry_shards = 2\nqueue_cap = 64\nsubmit_timeout_ms = 50\n\
             request_timeout_ms = 2000\nscratch_pool = 3\n",
        )
        .unwrap();
        let cfg = Config::from_doc(&doc).unwrap();
        assert_eq!(cfg.serve.batch_utts, 8);
        assert_eq!(cfg.serve.flush_us, 500);
        assert_eq!(cfg.serve.workers, 4);
        assert_eq!(cfg.serve.registry_shards, 2);
        assert_eq!(cfg.serve.queue_cap, 64);
        assert_eq!(cfg.serve.submit_timeout_ms, 50);
        assert_eq!(cfg.serve.request_timeout_ms, 2000);
        assert_eq!(cfg.serve.scratch_pool, 3);
    }

    #[test]
    fn serve_admission_defaults_survive_partial_file() {
        let doc = Doc::parse("[serve]\nqueue_cap = 16\n").unwrap();
        let cfg = Config::from_doc(&doc).unwrap();
        assert_eq!(cfg.serve.queue_cap, 16);
        assert_eq!(cfg.serve.submit_timeout_ms, 250);
        assert_eq!(cfg.serve.request_timeout_ms, 10_000);
        assert_eq!(cfg.serve.scratch_pool, 8);
    }

    #[test]
    fn align_precision_defaults_to_f64_and_parses() {
        let cfg = Config::from_doc(&Doc::parse("[tvm]\nrank = 16\n").unwrap()).unwrap();
        assert_eq!(cfg.align.precision, AlignPrecision::F64);
        assert_eq!(cfg.serve.precision, AlignPrecision::F64);

        let cfg =
            Config::from_doc(&Doc::parse("[align]\nprecision = \"f32\"\n").unwrap()).unwrap();
        assert_eq!(cfg.align.precision, AlignPrecision::F32);
        // the serving mirror follows the one knob
        assert_eq!(cfg.serve.precision, AlignPrecision::F32);

        // an explicit [serve] precision overrides serving only
        let cfg = Config::from_doc(
            &Doc::parse("[align]\nprecision = \"f64\"\n[serve]\nprecision = \"f32\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.align.precision, AlignPrecision::F64);
        assert_eq!(cfg.serve.precision, AlignPrecision::F32);

        let err = Config::from_doc(&Doc::parse("[align]\nprecision = \"f16\"\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("align.precision"), "{err:#}");
        let err = Config::from_doc(&Doc::parse("[serve]\nprecision = \"bad\"\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("serve.precision"), "{err:#}");
    }

    #[test]
    fn cluster_defaults_and_overrides_parse() {
        // defaults survive an unrelated file
        let cfg = Config::from_doc(&Doc::parse("[tvm]\nrank = 16\n").unwrap()).unwrap();
        assert_eq!(cfg.cluster.replicas, 2);
        assert_eq!(cfg.cluster.route, RoutePolicy::LeastDepth);
        assert_eq!(cfg.cluster.max_failovers, 2);
        assert_eq!(cfg.cluster.drain_timeout_ms, 5_000);
        assert!(cfg.cluster.overrides.iter().all(|o| !o.is_override()));

        // full section + per-replica subsections
        let cfg = Config::from_doc(
            &Doc::parse(
                "[cluster]\nreplicas = 3\nroute = \"round_robin\"\n\
                 max_failovers = 1\ndrain_timeout_ms = 250\n\
                 [cluster.replica1]\nprecision = \"f32\"\nworkers = 4\n\
                 [cluster.replica2]\nbatch_utts = 8\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.cluster.replicas, 3);
        assert_eq!(cfg.cluster.route, RoutePolicy::RoundRobin);
        assert_eq!(cfg.cluster.max_failovers, 1);
        assert_eq!(cfg.cluster.drain_timeout_ms, 250);
        assert!(!cfg.cluster.overrides[0].is_override());
        assert_eq!(cfg.cluster.overrides[1].precision, Some(AlignPrecision::F32));
        assert_eq!(cfg.cluster.overrides[1].workers, Some(4));
        assert_eq!(cfg.cluster.overrides[1].batch_utts, None);
        assert_eq!(cfg.cluster.overrides[2].batch_utts, Some(8));

        // the override applies on top of the shared [serve] base
        let r1 = cfg.cluster.replica_serve_cfg(&cfg.serve, 1);
        assert_eq!(r1.precision, AlignPrecision::F32);
        assert_eq!(r1.workers, 4);
        assert_eq!(r1.batch_utts, cfg.serve.batch_utts, "unset fields inherit [serve]");
        let r0 = cfg.cluster.replica_serve_cfg(&cfg.serve, 0);
        assert_eq!(r0.precision, cfg.serve.precision);
        assert_eq!(r0.workers, cfg.serve.workers);

        // bad spellings are nameable errors
        let err = Config::from_doc(&Doc::parse("[cluster]\nroute = \"random\"\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("cluster.route"), "{err:#}");
        let err = Config::from_doc(
            &Doc::parse("[cluster.replica0]\nprecision = \"f16\"\n").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("replica0"), "{err:#}");
        // replicas is clamped to ≥ 1, never 0
        let cfg =
            Config::from_doc(&Doc::parse("[cluster]\nreplicas = 0\n").unwrap()).unwrap();
        assert_eq!(cfg.cluster.replicas, 1);

        // a replica section outside 0..replicas is an error, not dead
        // config (the 1-based-numbering mistake)
        let err = Config::from_doc(
            &Doc::parse("[cluster]\nreplicas = 2\n[cluster.replica2]\nprecision = \"f32\"\n")
                .unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("0-based"), "{err:#}");
        let err = Config::from_doc(
            &Doc::parse("[cluster.replicaX]\nworkers = 1\n").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("must be a number"), "{err:#}");
        // ...and so is a typo'd override field inside a valid section
        let err = Config::from_doc(
            &Doc::parse("[cluster.replica0]\nqueue_cap = 4\n").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown per-replica override"), "{err:#}");
        // ...and a non-canonical id the reader would never probe
        let err = Config::from_doc(
            &Doc::parse("[cluster.replica01]\nprecision = \"f32\"\n").unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("leading zeros"), "{err:#}");
    }

    #[test]
    fn registry_section_defaults_and_overrides() {
        // defaults: volatile (no path), WAL on, sync always
        let cfg = Config::from_doc(&Doc::parse("[tvm]\nrank = 16\n").unwrap()).unwrap();
        assert_eq!(cfg.registry.path, None);
        assert!(cfg.registry.wal);
        assert_eq!(cfg.registry.sync, WalSync::Always);
        assert_eq!(cfg.registry.compact_every, 10_000);

        // full section, integer sync spelling
        let cfg = Config::from_doc(
            &Doc::parse(
                "[registry]\npath = \"./work/registry\"\nwal = true\n\
                 sync = 64\ncompact_every = 5000\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.registry.path.as_deref(), Some("./work/registry"));
        assert_eq!(cfg.registry.sync, WalSync::EveryN(64));
        assert_eq!(cfg.registry.compact_every, 5000);

        // string sync spelling, and 1 normalizes to always
        let cfg = Config::from_doc(
            &Doc::parse("[registry]\nsync = \"always\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.registry.sync, WalSync::Always);
        assert_eq!(WalSync::parse("1").unwrap(), WalSync::Always);
        assert_eq!(WalSync::EveryN(8).to_string(), "every-8");

        // bad values and typo'd keys are nameable errors, not silence
        let err = Config::from_doc(&Doc::parse("[registry]\nsync = 0\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("registry.sync"), "{err:#}");
        let err = Config::from_doc(&Doc::parse("[registry]\nsync = \"never\"\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("registry.sync"), "{err:#}");
        let err = Config::from_doc(&Doc::parse("[registry]\nsink = 4\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("unknown [registry] field"), "{err:#}");
    }

    #[test]
    fn obs_section_defaults_and_overrides() {
        // defaults: on, keep every completed trace, 64-deep ring
        let cfg = Config::from_doc(&Doc::parse("[tvm]\nrank = 16\n").unwrap()).unwrap();
        assert!(cfg.obs.enabled);
        assert_eq!(cfg.obs.trace_threshold_ms, 0.0);
        assert_eq!(cfg.obs.trace_ring, 64);

        let cfg = Config::from_doc(
            &Doc::parse(
                "[obs]\nenabled = false\ntrace_threshold_ms = 2.5\ntrace_ring = 256\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert!(!cfg.obs.enabled);
        assert_eq!(cfg.obs.trace_threshold_ms, 2.5);
        assert_eq!(cfg.obs.trace_ring, 256);

        // typo'd keys are nameable errors, not silently-dead config
        let err = Config::from_doc(&Doc::parse("[obs]\ntrace_rings = 8\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("unknown [obs] field"), "{err:#}");
    }

    #[test]
    fn session_section_defaults_and_overrides() {
        // defaults: 1024-session table, 30 s idle, thresholds disabled
        let cfg = Config::from_doc(&Doc::parse("[tvm]\nrank = 16\n").unwrap()).unwrap();
        assert_eq!(cfg.serve.session.max_sessions, 1024);
        assert_eq!(cfg.serve.session.idle_ms, 30_000);
        assert_eq!(cfg.serve.session.shards, 16);
        assert_eq!(cfg.serve.session.min_frames, 60);
        assert_eq!(cfg.serve.session.accept_score, None);
        assert_eq!(cfg.serve.session.reject_score, None);

        let cfg = Config::from_doc(
            &Doc::parse(
                "[session]\nmax_sessions = 8\nidle_ms = 500\nshards = 2\n\
                 min_frames = 40\naccept_score = 3.5\nreject_score = -1.25\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.serve.session.max_sessions, 8);
        assert_eq!(cfg.serve.session.idle_ms, 500);
        assert_eq!(cfg.serve.session.shards, 2);
        assert_eq!(cfg.serve.session.min_frames, 40);
        assert_eq!(cfg.serve.session.accept_score, Some(3.5));
        assert_eq!(cfg.serve.session.reject_score, Some(-1.25));

        // the session knobs ride [serve] through per-replica derivation
        let derived = cfg.cluster.replica_serve_cfg(&cfg.serve, 0);
        assert_eq!(derived.session, cfg.serve.session);

        // degenerate capacities are clamped, not honored
        let cfg = Config::from_doc(
            &Doc::parse("[session]\nmax_sessions = 0\nshards = 0\n").unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.serve.session.max_sessions, 1);
        assert_eq!(cfg.serve.session.shards, 1);

        // typo'd keys are nameable errors, not silently-dead config
        let err = Config::from_doc(&Doc::parse("[session]\nidle_secs = 30\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("unknown [session] field"), "{err:#}");
    }

    #[test]
    fn capture_section_parses_and_rejects_typos() {
        // defaults: enabled, full capture
        let cfg = Config::from_doc(&Doc::parse("").unwrap()).unwrap();
        assert!(cfg.capture.enabled);
        assert_eq!(cfg.capture.policy, SamplePolicy::All);

        let cfg = Config::from_doc(
            &Doc::parse(
                "[capture]\nenabled = true\npolicy = \"rate 8\"\nqueue = 64\nsync_every = 16\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.capture.policy, SamplePolicy::Rate(8));
        assert_eq!(cfg.capture.queue, 64);
        assert_eq!(cfg.capture.sync_every, 16);

        // every policy spelling round-trips through as_str
        for p in [
            SamplePolicy::All,
            SamplePolicy::Rate(3),
            SamplePolicy::SlowOnly,
            SamplePolicy::ErrorsOnly,
        ] {
            assert_eq!(SamplePolicy::parse(&p.as_str()).unwrap(), p);
        }

        let err = Config::from_doc(&Doc::parse("[capture]\npolicy = \"most\"\n").unwrap())
            .unwrap_err();
        // the parse error rides behind the `capture.policy` context, so
        // check the full chain
        assert!(format!("{err:#}").contains("capture policy must be"), "{err:#}");
        let err = Config::from_doc(&Doc::parse("[capture]\nqueue_len = 9\n").unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("unknown [capture] field"), "{err:#}");
    }

    #[test]
    fn type_errors_reported() {
        let doc = Doc::parse("[tvm]\nrank = \"oops\"\n").unwrap();
        assert!(Config::from_doc(&doc).is_err());
    }

    #[test]
    fn bad_syntax_rejected() {
        assert!(Doc::parse("key value no equals").is_err());
        assert!(Doc::parse("[unclosed\n").is_err());
    }
}
