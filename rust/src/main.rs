//! `ivector-tv` launcher — see [`ivector_tv::cli`] for the command set.

fn main() {
    std::process::exit(ivector_tv::cli::main());
}
