//! Trial lists and detection metrics (EER, minDCF, DET points).
//!
//! The paper evaluates on the VoxCeleb1 protocol: 37 720 trials with an
//! equal number of target and non-target pairs, pooled EER. We generate
//! a balanced trial list over the held-out synthetic speakers the same
//! way and compute EER by ROC sweep plus NIST-style minDCF.

use crate::rng::Rng;

/// One verification trial: enrollment utterance index, test utterance
/// index (into the eval i-vector list), and ground truth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Trial {
    pub enroll: usize,
    pub test: usize,
    pub target: bool,
}

/// Balanced trial list generated from utterance speaker labels:
/// `n_trials/2` same-speaker and `n_trials/2` different-speaker pairs,
/// sampled without replacement where possible.
pub fn generate_trials(spk_of_utt: &[usize], n_trials: usize, seed: u64) -> Vec<Trial> {
    let n = spk_of_utt.len();
    assert!(n >= 2, "need at least two utterances");
    let mut rng = Rng::seed(seed);

    // enumerate all candidate pairs once (eval sets are small)
    let mut targets = Vec::new();
    let mut nontargets = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if spk_of_utt[i] == spk_of_utt[j] {
                targets.push((i, j));
            } else {
                nontargets.push((i, j));
            }
        }
    }
    assert!(!targets.is_empty(), "no same-speaker pairs available");
    rng.shuffle(&mut targets);
    rng.shuffle(&mut nontargets);

    let half = n_trials / 2;
    let mut out = Vec::with_capacity(half * 2);
    for k in 0..half {
        let (e, t) = targets[k % targets.len()];
        out.push(Trial { enroll: e, test: t, target: true });
    }
    for k in 0..half {
        let (e, t) = nontargets[k % nontargets.len()];
        out.push(Trial { enroll: e, test: t, target: false });
    }
    rng.shuffle(&mut out);
    out
}

/// Detection metrics computed from scored trials.
#[derive(Debug, Clone)]
pub struct DetMetrics {
    /// Equal error rate in percent.
    pub eer_pct: f64,
    /// minDCF at p_target = 0.01 (c_miss = c_fa = 1).
    pub min_dcf_01: f64,
    /// minDCF at p_target = 0.001.
    pub min_dcf_001: f64,
}

/// Compute EER + minDCF from (score, is_target) pairs via threshold sweep.
pub fn det_metrics(scores: &[(f64, bool)]) -> DetMetrics {
    let n_tgt = scores.iter().filter(|(_, t)| *t).count();
    let n_non = scores.len() - n_tgt;
    assert!(n_tgt > 0 && n_non > 0, "need both target and non-target trials");

    // sort descending by score; sweep the threshold through every score
    let mut sorted: Vec<(f64, bool)> = scores.to_vec();
    sorted.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    // at threshold above max score: accept none → P_miss=1, P_fa=0
    let mut accepted_tgt = 0usize;
    let mut accepted_non = 0usize;
    let mut eer = f64::NAN;
    let mut best_gap = f64::INFINITY;
    let mut min_dcf_01 = f64::INFINITY;
    let mut min_dcf_001 = f64::INFINITY;

    let mut i = 0;
    while i <= sorted.len() {
        let p_miss = 1.0 - accepted_tgt as f64 / n_tgt as f64;
        let p_fa = accepted_non as f64 / n_non as f64;
        let gap = (p_miss - p_fa).abs();
        if gap < best_gap {
            best_gap = gap;
            eer = 0.5 * (p_miss + p_fa);
        }
        for (p_t, dcf) in [(0.01, &mut min_dcf_01), (0.001, &mut min_dcf_001)] {
            let c = p_t * p_miss + (1.0 - p_t) * p_fa;
            if c < *dcf {
                *dcf = c;
            }
        }
        if i == sorted.len() {
            break;
        }
        // accept the next-highest score (handle ties as a block)
        let s = sorted[i].0;
        while i < sorted.len() && sorted[i].0 == s {
            if sorted[i].1 {
                accepted_tgt += 1;
            } else {
                accepted_non += 1;
            }
            i += 1;
        }
    }

    // normalize minDCF by the best uninformed system, NIST style
    let norm_01 = 0.01f64.min(0.99);
    let norm_001 = 0.001f64.min(0.999);
    DetMetrics {
        eer_pct: eer * 100.0,
        min_dcf_01: min_dcf_01 / norm_01,
        min_dcf_001: min_dcf_001 / norm_001,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_zero_eer() {
        let scores: Vec<(f64, bool)> =
            (0..50).map(|i| (i as f64, false)).chain((0..50).map(|i| (100.0 + i as f64, true))).collect();
        let m = det_metrics(&scores);
        assert!(m.eer_pct < 1e-9, "{}", m.eer_pct);
        assert!(m.min_dcf_01 < 1e-9);
    }

    #[test]
    fn random_scores_eer_near_half() {
        let mut rng = Rng::seed(77);
        let scores: Vec<(f64, bool)> =
            (0..4000).map(|i| (rng.uniform(), i % 2 == 0)).collect();
        let m = det_metrics(&scores);
        assert!((m.eer_pct - 50.0).abs() < 5.0, "{}", m.eer_pct);
    }

    #[test]
    fn inverted_scores_eer_near_one() {
        // targets score LOW → EER ≈ 100%
        let scores: Vec<(f64, bool)> =
            (0..50).map(|i| (100.0 + i as f64, false)).chain((0..50).map(|i| (i as f64, true))).collect();
        let m = det_metrics(&scores);
        assert!(m.eer_pct > 95.0);
    }

    #[test]
    fn trial_list_balanced_and_valid() {
        // 6 speakers × 4 utts
        let spk: Vec<usize> = (0..24).map(|i| i / 4).collect();
        let trials = generate_trials(&spk, 200, 3);
        assert_eq!(trials.len(), 200);
        let n_tgt = trials.iter().filter(|t| t.target).count();
        assert_eq!(n_tgt, 100);
        for t in &trials {
            assert_ne!(t.enroll, t.test);
            assert_eq!(t.target, spk[t.enroll] == spk[t.test]);
        }
    }

    #[test]
    fn trial_list_deterministic() {
        let spk: Vec<usize> = (0..12).map(|i| i / 3).collect();
        assert_eq!(generate_trials(&spk, 50, 9), generate_trials(&spk, 50, 9));
    }

    #[test]
    fn eer_known_value() {
        // one mistake each way out of 4 → EER 50%? Construct:
        // targets: 3, 1; nontargets: 2, 0. Threshold at 1.5: miss=1/2, fa=1/2 → EER 50.
        let scores = vec![(3.0, true), (1.0, true), (2.0, false), (0.0, false)];
        let m = det_metrics(&scores);
        assert!((m.eer_pct - 50.0).abs() < 1e-9, "{}", m.eer_pct);
    }
}
