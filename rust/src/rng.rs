//! Deterministic PRNG substrate: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic step in the stack (corpus synthesis, T-matrix random
//! initialization, trial sampling) flows through this module, so entire
//! ensemble runs are reproducible from a single `u64` seed — the paper's
//! "five runs with random start" become five seeds.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (any u64 is a valid seed).
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], cached_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-speaker rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.cached_normal = Some(r * s);
        r * c
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Sample an index from an (unnormalized) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Symmetric Dirichlet(alpha) sample via Gamma(alpha) draws
    /// (Marsaglia–Tsang, with the alpha<1 boost).
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Gamma(shape, 1) sample.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.uniform().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Rng::seed(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed(5);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::seed(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f2 - 0.7).abs() < 0.02, "{counts:?}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Rng::seed(8);
        let d = rng.dirichlet(2.0, 10);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn gamma_mean() {
        let mut rng = Rng::seed(12);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.gamma(3.5)).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "{mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed(4);
        let mut v: Vec<usize> = (0..20).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut rng = Rng::seed(6);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }
}
