//! Minimal, dependency-free reimplementation of the `anyhow` API subset
//! this repository uses, vendored so tier-1 (`cargo build --release &&
//! cargo test -q`) resolves in a network-less container.
//!
//! Covered (drop-in compatible for these uses):
//!
//! * [`Error`] — boxed dynamic error with a context chain, convertible
//!   from any `std::error::Error + Send + Sync + 'static` via `?`;
//! * [`Result<T>`] — alias with `Error` as the default error type;
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result`
//!   and `Option`;
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — format-style constructors;
//! * [`Error::downcast_ref`] — typed access to the root error (how the
//!   serving path exposes its typed overload/timeout errors);
//! * `{e}` prints the outermost message, `{e:#}` the full
//!   colon-separated chain, matching anyhow's display contract.
//!
//! Not covered (unused here): backtraces, `downcast`/`downcast_mut` by
//! value, `chain()` iteration, `#[source]` attribute interplay.
//! **Known divergence:** `anyhow!(err_value)` with a non-literal single
//! expression stringifies the value into an ad-hoc message (real anyhow
//! preserves error values for later `downcast_ref`). To keep a typed
//! error downcastable, convert with `Error::new(err)` / `err.into()`
//! instead of `anyhow!(err)` — every current call site in this repo
//! uses the format-literal forms, which behave identically.
//!
//! Clean-room implementation against the documented anyhow API; no
//! upstream code was copied.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error plus a chain of human-readable context layers
/// (outermost first). Deliberately does **not** implement
/// `std::error::Error`, exactly like anyhow's `Error` — that is what
/// keeps the blanket `From<E: StdError>` conversion coherent.
pub struct Error {
    /// Context layers added by [`Context`], outermost first.
    context: Vec<String>,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

/// Ad-hoc message error backing [`anyhow!`].
#[derive(Debug)]
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

impl Error {
    /// Create from a displayable message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { context: Vec::new(), source: Box::new(Message(message.to_string())) }
    }

    /// Create from a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self { context: Vec::new(), source: Box::new(error) }
    }

    /// Wrap with an outer context layer (consuming builder form; the
    /// trait method on `Result` is the usual entry point).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.context.insert(0, context.to_string());
        self
    }

    /// The root error, if it is an `E`. Context layers do not hide the
    /// root: a typed error stays downcastable through any number of
    /// `.context(...)` wrappers.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.source.downcast_ref::<E>()
    }

    /// The root cause (the error the chain bottoms out at).
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = &*self.source;
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first, colon-joined
            for c in &self.context {
                write!(f, "{c}: ")?;
            }
            write!(f, "{}", self.source)?;
            let mut cause = self.source.source();
            while let Some(next) = cause {
                write!(f, ": {next}")?;
                cause = next.source();
            }
            Ok(())
        } else if let Some(c) = self.context.first() {
            f.write_str(c)
        } else {
            write!(f, "{}", self.source)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow prints the message plus a caused-by chain; the
        // colon-joined alternate form carries the same information
        write!(f, "{self:#}")
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Attach a context message, converting the error to [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Lazily-evaluated [`Context::context`].
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl StdError for Typed {}

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(-1).unwrap_err().to_string().contains("positive"));
    }

    #[test]
    fn context_layers_and_alternate_chain() {
        let r: Result<()> = Err(Error::new(Typed(7)));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: typed error 7");
        // context does not hide the typed root
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.root_cause().is::<Typed>());
    }

    #[test]
    fn with_context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3");
        let n: Option<u8> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").unwrap_err().to_string().contains("invalid digit"));
    }
}
