//! Integration tests across the three layers: the accelerated device
//! path must reproduce the CPU reference (same math, f32 on device),
//! and the full pipeline must produce a working speaker verifier.
//!
//! These tests need `artifacts/` (run `make artifacts` first — the
//! Makefile test target guarantees the ordering).

use ivector_tv::config::Config;
use ivector_tv::coordinator::{
    align_archive_accel, align_archive_cpu, stats_from_posts, ComputePath, TrainSetup,
};
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::{train_ubm, UbmPair};
use ivector_tv::io::FeatArchive;
use ivector_tv::ivector::{
    estep_utterance, extract_cpu, AccelTvm, EstepAccum, Formulation, TrainVariant, TvModel,
    UttStats,
};

/// Scaled-down corpus at the *artifact* dims (C=64, F=24, R=64).
fn artifact_scale_setup() -> (Config, FeatArchive, FeatArchive, UbmPair) {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 48;
    cfg.corpus.utts_per_train_speaker = 5;
    cfg.corpus.n_eval_speakers = 12;
    cfg.corpus.utts_per_eval_speaker = 4;
    cfg.corpus.min_frames = 150;
    cfg.corpus.max_frames = 250;
    cfg.ubm.train_frames = 20_000;
    cfg.ubm.diag_em_iters = 3;
    cfg.ubm.full_em_iters = 1;
    cfg.tvm.iters = 3;
    // LDA needs out_dim < n_speakers (between-class scatter rank)
    cfg.backend.lda_dim = 16;
    let corpus = generate_corpus(&cfg.corpus).unwrap();
    let (ubm, _) = train_ubm(&corpus.train, &cfg.ubm, 1).unwrap();
    (cfg, corpus.train, corpus.eval, ubm)
}

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.toml").exists()
}

#[test]
fn accel_alignment_matches_cpu_reference() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts` before `cargo test`");
    }
    let (cfg, train, _eval, ubm) = artifact_scale_setup();
    let accel = AccelTvm::new("artifacts").unwrap().with_alignment().unwrap();

    let cpu = align_archive_cpu(&ubm.diag, &ubm.full, &train, cfg.tvm.top_k, cfg.tvm.min_post, 4);
    let dev = align_archive_accel(&accel, &ubm.diag, &ubm.full, &train).unwrap();

    assert_eq!(cpu.len(), dev.len());
    let mut mismatched_frames = 0usize;
    let mut total_frames = 0usize;
    for (cu, du) in cpu.iter().zip(&dev) {
        assert_eq!(cu.len(), du.len());
        for (cf, df) in cu.iter().zip(du) {
            total_frames += 1;
            let mut c_map: std::collections::HashMap<u32, f32> =
                cf.iter().map(|p| (p.idx, p.post)).collect();
            let mut ok = c_map.len() == df.len();
            if ok {
                for p in df {
                    match c_map.remove(&p.idx) {
                        Some(cp) if (cp - p.post).abs() < 5e-3 => {}
                        _ => {
                            ok = false;
                            break;
                        }
                    }
                }
            }
            if !ok {
                mismatched_frames += 1;
            }
        }
    }
    // f32 softmax near the pruning threshold can flip a component in/out
    // on rare frames; demand equality on ≥ 99.5% of frames.
    let rate = mismatched_frames as f64 / total_frames as f64;
    assert!(rate < 5e-3, "{mismatched_frames}/{total_frames} frames disagree ({rate:.4})");
}

#[test]
fn accel_estep_matches_cpu_reference() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts`");
    }
    let (cfg, train, _eval, ubm) = artifact_scale_setup();
    let model = TvModel::init(Formulation::Augmented, &ubm.full, cfg.tvm.rank, 100.0, 5);

    let posts = align_archive_cpu(&ubm.diag, &ubm.full, &train, cfg.tvm.top_k, cfg.tvm.min_post, 4);
    let (bw, _) = stats_from_posts(&train, &posts, cfg.ubm.components, 4);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &model)).collect();

    // CPU accumulation
    let (tt_si, tt_si_t) = model.precompute();
    let mut cpu_acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
    for s in &utts {
        estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut cpu_acc));
    }

    // device accumulation
    let mut accel = AccelTvm::new("artifacts").unwrap();
    accel.set_model(&model).unwrap();
    let mut dev_acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
    let bu = accel.dims.bu;
    for chunk in utts.chunks(bu) {
        let refs: Vec<&UttStats> = chunk.iter().collect();
        let (acc, _phi) = accel.estep_batch(&refs).unwrap();
        dev_acc.merge(&acc);
    }

    assert_eq!(dev_acc.count, cpu_acc.count);
    let rel = |a: f64, b: f64| (a - b).abs() / (1.0 + a.abs().max(b.abs()));
    for (i, (a, b)) in dev_acc.h.iter().zip(&cpu_acc.h).enumerate() {
        assert!(rel(*a, *b) < 2e-3, "h[{i}]: {a} vs {b}");
    }
    let hh_dev = dev_acc.hh.sub(&cpu_acc.hh).max_abs() / (1.0 + cpu_acc.hh.max_abs());
    assert!(hh_dev < 2e-3, "H deviates by {hh_dev}");
    for c in 0..cfg.ubm.components {
        let da = dev_acc.a[c].sub(&cpu_acc.a[c]).max_abs() / (1.0 + cpu_acc.a[c].max_abs());
        let db = dev_acc.b[c].sub(&cpu_acc.b[c]).max_abs() / (1.0 + cpu_acc.b[c].max_abs());
        assert!(da < 3e-3, "A[{c}] deviates by {da}");
        assert!(db < 3e-3, "B[{c}] deviates by {db}");
    }
}

#[test]
fn accel_extraction_matches_cpu_reference() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts`");
    }
    let (cfg, train, _eval, ubm) = artifact_scale_setup();
    let model = TvModel::init(Formulation::Augmented, &ubm.full, cfg.tvm.rank, 100.0, 9);
    let posts = align_archive_cpu(&ubm.diag, &ubm.full, &train, cfg.tvm.top_k, cfg.tvm.min_post, 4);
    let (bw, _) = stats_from_posts(&train, &posts, cfg.ubm.components, 4);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &model)).collect();

    let cpu_iv = extract_cpu(&model, &utts, 4);

    let mut accel = AccelTvm::new("artifacts").unwrap();
    accel.set_model(&model).unwrap();
    let mut rows = Vec::new();
    for chunk in utts.chunks(accel.dims.bu) {
        let refs: Vec<&UttStats> = chunk.iter().collect();
        let iv = accel.extract_batch(&refs, &model.prior_mean).unwrap();
        for i in 0..iv.rows() {
            rows.push(iv.row(i).to_vec());
        }
    }
    assert_eq!(rows.len(), cpu_iv.rows());
    for (i, row) in rows.iter().enumerate() {
        for (j, v) in row.iter().enumerate() {
            let want = cpu_iv.get(i, j);
            assert!(
                (v - want).abs() < 2e-3 * (1.0 + want.abs()),
                "iv[{i}][{j}]: {v} vs {want}"
            );
        }
    }
}

#[test]
fn end_to_end_training_produces_working_verifier() {
    if !have_artifacts() {
        panic!("artifacts missing — run `make artifacts`");
    }
    let (cfg, train, eval, ubm) = artifact_scale_setup();
    let mut accel = AccelTvm::new("artifacts").unwrap().with_alignment().unwrap();
    let variant = TrainVariant::recommended(2);
    let mut setup = TrainSetup {
        cfg: &cfg,
        feats: &train,
        diag: ubm.diag.clone(),
        full: ubm.full.clone(),
    };
    let (model, curve) = ivector_tv::coordinator::ensemble::run_curve(
        &cfg,
        &train,
        &eval,
        &setup.diag,
        &setup.full,
        variant,
        3,
        42,
        1,
        ComputePath::Accel,
        Some(&mut accel),
    )
    .unwrap();
    let _ = &mut setup;
    assert_eq!(curve.eer_by_iter.len(), 3);
    let final_eer = *curve.eer_by_iter.last().unwrap();
    // synthetic speakers are separable by construction: far below chance
    assert!(final_eer < 45.0, "EER {final_eer:.1}% — verifier not working");
    assert_eq!(model.rank(), cfg.tvm.rank);
}
