//! Stage-level integration: the CLI pipeline run end-to-end through the
//! public stage functions (disk round-trips included), plus failure
//! injection on the archive/model formats.

use ivector_tv::cli::Args;
use ivector_tv::coordinator::stages;
use ivector_tv::io::{load, save, BinReader, FeatArchive};
use ivector_tv::ivector::TvModel;

fn args(pairs: &[(&str, &str)], switches: &[&str]) -> Args {
    let mut argv: Vec<String> = Vec::new();
    for (k, v) in pairs {
        argv.push(format!("--{k}"));
        argv.push(v.to_string());
    }
    for s in switches {
        argv.push(format!("--{s}"));
    }
    Args::parse(&argv).unwrap()
}

fn tiny_config_file(dir: &std::path::Path) -> String {
    let path = dir.join("tiny.toml");
    std::fs::write(
        &path,
        "[corpus]\n\
         n_train_speakers = 20\n\
         utts_per_train_speaker = 4\n\
         n_eval_speakers = 6\n\
         utts_per_eval_speaker = 3\n\
         min_frames = 120\n\
         max_frames = 200\n\
         [ubm]\n\
         diag_em_iters = 2\n\
         full_em_iters = 1\n\
         train_frames = 8000\n\
         [tvm]\n\
         iters = 2\n\
         [backend]\n\
         lda_dim = 12\n\
         plda_iters = 3\n\
         [trials]\n\
         n_trials = 1000\n",
    )
    .unwrap();
    path.to_str().unwrap().to_string()
}

#[test]
fn cli_pipeline_end_to_end_on_disk() {
    let dir = std::env::temp_dir().join("ivtv_pipeline_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = tiny_config_file(&dir);
    let work = dir.join("work");
    let work_s = work.to_str().unwrap();

    // stage by stage, each reading the previous stage's disk outputs
    let base = |extra: &[(&str, &str)], sw: &[&str]| {
        let mut pairs = vec![("config", cfg_path.as_str()), ("work", work_s)];
        pairs.extend_from_slice(extra);
        args(&pairs, sw)
    };
    stages::synth(&base(&[], &[])).unwrap();
    assert!(work.join("train.feats").exists());
    stages::train_ubm(&base(&[], &[])).unwrap();
    stages::align(&base(&[], &["cpu-ref"])).unwrap();
    assert!(work.join("train.posts").exists());
    stages::train(&base(&[("iters", "2"), ("variant", "aug")], &["sigma", "cpu-ref"])).unwrap();
    let model: TvModel = load(work.join("tvm.bin")).unwrap();
    assert_eq!(model.rank(), 64);
    stages::extract(&base(&[], &[])).unwrap();
    stages::backend(&base(&[], &[])).unwrap();
    stages::eval(&base(&[], &[])).unwrap();
    stages::bundle(&base(&[], &[])).unwrap();
    assert!(work.join("bundle.bin").exists());

    // the bundle serves: enroll/verify round-trip through the engine
    let cfg = ivector_tv::config::Config::load(&cfg_path).unwrap();
    let bundle =
        ivector_tv::serve::ModelBundle::load_auto(work.to_str().unwrap(), &cfg).unwrap();
    let engine = ivector_tv::serve::Engine::new(bundle, &cfg.serve).unwrap();
    let eval_arch: FeatArchive = FeatArchive::load(work.join("eval.feats")).unwrap();
    let (u0, u1) = (&eval_arch.utts[0], &eval_arch.utts[1]);
    assert_eq!(u0.spk_id, u1.spk_id, "eval archive groups utts per speaker");
    engine.enroll(&u0.spk_id, &u0.feats).unwrap();
    let out = engine.verify(&u0.spk_id, &u1.feats).unwrap();
    assert!(out.score.is_finite());
    assert_eq!(out.enrolled_utts, 1);

    // stage outputs reload cleanly
    let train: FeatArchive = FeatArchive::load(work.join("train.feats")).unwrap();
    assert_eq!(train.utts.len(), 80);
    let posts = ivector_tv::io::PostArchive::load(work.join("train.posts")).unwrap();
    assert_eq!(posts.utts.len(), 80);
    // postings per frame in the pruned regime the paper reports (~4)
    let avg: f64 =
        posts.utts.iter().map(|u| u.avg_postings()).sum::<f64>() / posts.utts.len() as f64;
    assert!(avg >= 1.0 && avg <= 10.0, "avg postings {avg}");
}

#[test]
fn corrupt_archive_is_rejected_not_misread() {
    let dir = std::env::temp_dir().join("ivtv_corrupt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("feats.bin");
    // write a valid archive then truncate it mid-payload
    let arch = FeatArchive {
        utts: vec![ivector_tv::io::Utterance {
            utt_id: "u".into(),
            spk_id: "s".into(),
            feats: ivector_tv::linalg::Mat::zeros(100, 24),
        }],
    };
    arch.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(FeatArchive::load(&path).is_err(), "truncated archive must fail to load");

    // flip the magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&path, &bad).unwrap();
    assert!(FeatArchive::load(&path).is_err(), "bad magic must be rejected");
}

#[test]
fn model_files_are_not_interchangeable() {
    // loading a TvModel from a GMM file must fail cleanly, not alias
    let dir = std::env::temp_dir().join("ivtv_mix_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("diag.bin");
    let gmm = ivector_tv::gmm::DiagGmm {
        weights: vec![1.0],
        means: ivector_tv::linalg::Mat::zeros(1, 4),
        vars: ivector_tv::linalg::Mat::from_fn(1, 4, |_, _| 1.0),
    };
    save(&gmm, &path).unwrap();
    let res: anyhow::Result<TvModel> = load(&path);
    assert!(res.is_err(), "cross-type load must error");
}

#[test]
fn reader_rejects_implausible_lengths() {
    // a header claiming a ludicrous string length must error, not OOM
    let dir = std::env::temp_dir().join("ivtv_len_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("evil.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"IVTV");
    bytes.extend_from_slice(&1u32.to_le_bytes()); // version
    bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // "string length"
    std::fs::write(&path, &bytes).unwrap();
    let mut r = BinReader::open(&path).unwrap();
    assert!(r.read_string().is_err());
}

#[test]
fn unknown_cli_flags_are_reported() {
    let a = args(&[("bogus-flag", "1")], &[]);
    assert!(stages::synth(&a).is_err());
}

#[test]
fn config_dim_mismatch_fails_fast_on_accel() {
    // a model whose dims disagree with the artifacts must be refused by
    // the accel path with an actionable error
    if !std::path::Path::new("artifacts/manifest.toml").exists() {
        panic!("artifacts missing — run `make artifacts`");
    }
    let ubm = {
        let mut rng = ivector_tv::rng::Rng::seed(1);
        let means = ivector_tv::linalg::Mat::from_fn(8, 6, |_, _| rng.normal());
        let covs = (0..8).map(|_| ivector_tv::linalg::Mat::eye(6)).collect();
        ivector_tv::gmm::FullGmm::new(vec![0.125; 8], means, covs).unwrap()
    };
    let model = TvModel::init(ivector_tv::ivector::Formulation::Augmented, &ubm, 5, 100.0, 1);
    let mut accel = ivector_tv::ivector::AccelTvm::new("artifacts").unwrap();
    let err = accel.set_model(&model).unwrap_err();
    assert!(err.to_string().contains("do not match artifacts"), "{err}");
}
