//! Bench: backend scoring — CPU PLDA score matrix vs the `plda_score`
//! device graph, plus EER computation (trial-list sweep).

use ivector_tv::backend::Plda;
use ivector_tv::bench_util::bench;
use ivector_tv::linalg::Mat;
use ivector_tv::rng::Rng;
use ivector_tv::runtime::{Runtime, Tensor};
use ivector_tv::trials::{det_metrics, generate_trials};

fn main() {
    let d = 32; // must match artifacts manifest D
    let (ne, nt) = (256, 256);
    let mut rng = Rng::seed(1);

    // labeled data → PLDA
    let n_spk = 60;
    let per = 8;
    let mut x = Mat::zeros(n_spk * per, d);
    let mut labels = Vec::new();
    for s in 0..n_spk {
        let y: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        for _ in 0..per {
            let i = labels.len();
            for j in 0..d {
                x.set(i, j, y[j] + 0.6 * rng.normal());
            }
            labels.push(s);
        }
    }
    let plda = Plda::fit(&x, &labels, 5).unwrap();
    let enroll = Mat::from_fn(ne, d, |_, _| rng.normal());
    let test = Mat::from_fn(nt, d, |_, _| rng.normal());

    println!("scoring bench: {ne}x{nt} trials, D={d}");
    let cpu = bench("plda-score/cpu", 1, 10, || plda.score_matrix(&enroll, &test));

    // device path
    let mut rt = Runtime::cpu("artifacts").unwrap();
    rt.load("plda_score").unwrap();
    let graph = rt.graph("plda_score").unwrap();
    let pack = |m: &Mat| Tensor::from_f64(m.as_slice(), &[m.rows(), m.cols()]);
    let (p_t, q_t) = (pack(&plda.p), pack(&plda.q));
    let (e_t, t_t) = (pack(&enroll), pack(&test));
    let dev = bench("plda-score/accel", 1, 10, || {
        graph.run(&[e_t.clone(), t_t.clone(), p_t.clone(), q_t.clone()]).unwrap()
    });
    println!("-> scoring speedup accel/cpu: {:.2}x", cpu.median_s / dev.median_s);

    // device vs CPU numerics
    let out = graph.run(&[e_t, t_t, p_t, q_t]).unwrap();
    let dev_scores = out[0].to_f64().unwrap();
    let cpu_scores = plda.score_matrix(&enroll, &test);
    let mut max_err = 0.0f64;
    for i in 0..ne {
        for j in 0..nt {
            max_err = max_err.max(
                (dev_scores[i * nt + j] - cpu_scores.get(i, j)).abs()
                    / (1.0 + cpu_scores.get(i, j).abs()),
            );
        }
    }
    println!("plda-score accel vs cpu max rel err: {max_err:.2e}");
    assert!(max_err < 1e-3, "device scoring diverged");

    // EER sweep cost
    let spk: Vec<usize> = (0..200).map(|i| i / 4).collect();
    let trials = generate_trials(&spk, 8000, 3);
    let scores: Vec<(f64, bool)> = trials.iter().map(|t| (rng.normal(), t.target)).collect();
    bench("eer/8000-trials", 1, 20, || det_metrics(&scores));
}
