//! Bench: frame alignment (paper §4.2 — the 3000×-RT claim).
//! CPU reference vs accelerated `align_topk` graph on identical frames.

use ivector_tv::bench_util::bench;
use ivector_tv::config::Config;
use ivector_tv::coordinator::{align_archive_accel, align_archive_cpu};
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::AccelTvm;
use ivector_tv::metrics::rt_factor;

fn main() {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 16;
    cfg.corpus.utts_per_train_speaker = 4;
    let corpus = generate_corpus(&cfg.corpus).unwrap();
    let train = &corpus.train;
    let frames = train.total_frames();
    let (ubm, _) = train_ubm(train, &cfg.ubm, 1).unwrap();
    let accel = AccelTvm::new("artifacts").unwrap().with_alignment().unwrap();
    let workers = ivector_tv::exec::default_workers();

    println!("alignment bench: {frames} frames ({} utts)", train.utts.len());
    let cpu = bench("align/cpu-ref", 1, 5, || {
        align_archive_cpu(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers)
    });
    let dev = bench("align/accel", 1, 5, || {
        align_archive_accel(&accel, &ubm.diag, &ubm.full, train).unwrap()
    });
    println!(
        "-> accel {:.0}x RT, cpu-ref {:.0}x RT, speedup {:.2}x",
        rt_factor(frames, dev.median_s),
        rt_factor(frames, cpu.median_s),
        cpu.median_s / dev.median_s
    );
}
