//! Bench: frame alignment (paper §4.2 — the 3000×-RT claim).
//! Scalar CPU reference vs the batched GEMM-shaped CPU aligner vs the
//! accelerated `align_topk` graph on identical frames. The accel case
//! is skipped (with a note) when `artifacts/` is absent, so the
//! CPU-only comparison runs everywhere.

use ivector_tv::bench_util::bench;
use ivector_tv::config::Config;
use ivector_tv::coordinator::{
    align_archive_accel, align_archive_cpu, align_archive_cpu_prec, align_archive_cpu_scalar,
};
use ivector_tv::gmm::AlignPrecision;
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::AccelTvm;
use ivector_tv::metrics::rt_factor;

fn main() {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 16;
    cfg.corpus.utts_per_train_speaker = 4;
    let corpus = generate_corpus(&cfg.corpus).unwrap();
    let train = &corpus.train;
    let frames = train.total_frames();
    let (ubm, _) = train_ubm(train, &cfg.ubm, 1).unwrap();
    let workers = ivector_tv::exec::default_workers();

    println!("alignment bench: {frames} frames ({} utts)", train.utts.len());
    let scalar = bench("align/cpu-scalar", 1, 5, || {
        align_archive_cpu_scalar(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers)
    });
    let batched = bench("align/cpu-batched", 1, 5, || {
        align_archive_cpu(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers)
    });
    let batched_f32 = bench("align/cpu-batched-f32", 1, 5, || {
        align_archive_cpu_prec(
            &ubm.diag,
            &ubm.full,
            train,
            cfg.tvm.top_k,
            cfg.tvm.min_post,
            workers,
            AlignPrecision::F32,
        )
    });
    println!(
        "-> cpu batched {:.0}x RT vs scalar {:.0}x RT: {:.2}x speedup; \
         f32 {:.0}x RT ({:.2}x over f64)",
        rt_factor(frames, batched.median_s),
        rt_factor(frames, scalar.median_s),
        scalar.median_s / batched.median_s,
        rt_factor(frames, batched_f32.median_s),
        batched.median_s / batched_f32.median_s
    );

    match AccelTvm::new("artifacts").and_then(AccelTvm::with_alignment) {
        Ok(accel) => {
            let dev = bench("align/accel", 1, 5, || {
                align_archive_accel(&accel, &ubm.diag, &ubm.full, train).unwrap()
            });
            println!(
                "-> accel {:.0}x RT, speedup {:.2}x over batched cpu",
                rt_factor(frames, dev.median_s),
                batched.median_s / dev.median_s
            );
        }
        Err(e) => println!("align/accel skipped (no artifacts): {e:#}"),
    }
}
