//! Bench: the TVM E-step hot loop — per-item scalar CPU, batched
//! GEMM-shaped CPU (single- and multi-threaded), and the accelerated
//! `estep` graph (paper's 25×-training claim). The accel case is
//! skipped when `artifacts/` is absent.

use ivector_tv::bench_util::bench;
use ivector_tv::config::Config;
use ivector_tv::coordinator::{align_archive_cpu, stats_from_posts};
use ivector_tv::exec::map_parallel;
use ivector_tv::ivector::{
    estep_batch_cpu, estep_utterance, AccelTvm, EstepAccum, EstepWorkspace, Formulation,
    TvModel, UttStats,
};

fn main() {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 24;
    cfg.corpus.utts_per_train_speaker = 6;
    let corpus = ivector_tv::frontend::synth::generate_corpus(&cfg.corpus).unwrap();
    let train = &corpus.train;
    let (ubm, _) = ivector_tv::gmm::train_ubm(train, &cfg.ubm, 1).unwrap();
    let workers = ivector_tv::exec::default_workers();
    let posts = align_archive_cpu(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers);
    let (bw, _) = stats_from_posts(train, &posts, cfg.ubm.components, workers);
    let model = TvModel::init(Formulation::Augmented, &ubm.full, cfg.tvm.rank, 100.0, 3);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &model)).collect();
    let (c, f, r) = (cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
    let bu = cfg.tvm.batch_utts.max(1);
    println!("estep bench: {} utts, C={c} F={f} R={r} BU={bu}", utts.len());

    let (tt_si, tt_si_t) = model.precompute();
    let scalar = bench("estep/cpu-scalar-1-thread", 1, 3, || {
        let mut acc = EstepAccum::zeros(c, f, r);
        for s in &utts {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        acc.count
    });

    let consts = model.precompute_consts();
    let batched = bench("estep/cpu-batched-1-thread", 1, 3, || {
        let mut acc = EstepAccum::zeros(c, f, r);
        let mut ws = EstepWorkspace::new(r, bu);
        for chunk in utts.chunks(bu) {
            let refs: Vec<&UttStats> = chunk.iter().collect();
            estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut acc));
        }
        acc.count
    });
    println!(
        "-> batched vs scalar (1 thread): {:.2}x",
        scalar.median_s / batched.median_s
    );

    let mt = bench("estep/cpu-batched-multithread", 1, 3, || {
        let chunk = utts.len().div_ceil(workers);
        let parts = map_parallel(utts.len().div_ceil(chunk), workers, |k| {
            let mut acc = EstepAccum::zeros(c, f, r);
            let mut ws = EstepWorkspace::new(r, bu);
            let slice = &utts[k * chunk..((k + 1) * chunk).min(utts.len())];
            for b in slice.chunks(bu) {
                let refs: Vec<&UttStats> = b.iter().collect();
                estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut acc));
            }
            acc
        });
        parts.len()
    });

    match AccelTvm::new("artifacts") {
        Ok(mut accel) => {
            accel.set_model(&model).unwrap();
            let dev = bench("estep/accel", 1, 3, || {
                let mut acc = EstepAccum::zeros(c, f, r);
                for chunk in utts.chunks(accel.dims.bu) {
                    let refs: Vec<&UttStats> = chunk.iter().collect();
                    let (a, _) = accel.estep_batch(&refs).unwrap();
                    acc.merge(&a);
                }
                acc.count
            });
            println!(
                "-> accel vs scalar {:.1}x, vs batched multithread {:.1}x",
                scalar.median_s / dev.median_s,
                mt.median_s / dev.median_s
            );
        }
        Err(e) => println!("estep/accel skipped (no artifacts): {e:#}"),
    }
}
