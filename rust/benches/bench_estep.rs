//! Bench: the TVM E-step hot loop — scalar CPU, multithreaded CPU,
//! and the accelerated `estep` graph (paper's 25×-training claim).

use ivector_tv::bench_util::bench;
use ivector_tv::config::Config;
use ivector_tv::coordinator::{align_archive_cpu, stats_from_posts};
use ivector_tv::exec::map_parallel;
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::{
    estep_utterance, AccelTvm, EstepAccum, Formulation, TvModel, UttStats,
};

fn main() {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 24;
    cfg.corpus.utts_per_train_speaker = 6;
    let corpus = generate_corpus(&cfg.corpus).unwrap();
    let train = &corpus.train;
    let (ubm, _) = train_ubm(train, &cfg.ubm, 1).unwrap();
    let workers = ivector_tv::exec::default_workers();
    let posts = align_archive_cpu(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers);
    let (bw, _) = stats_from_posts(train, &posts, cfg.ubm.components, workers);
    let model = TvModel::init(Formulation::Augmented, &ubm.full, cfg.tvm.rank, 100.0, 3);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &model)).collect();
    let (c, f, r) = (cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
    println!("estep bench: {} utts, C={c} F={f} R={r}", utts.len());

    let (tt_si, tt_si_t) = model.precompute();
    let scalar = bench("estep/cpu-1-thread", 1, 3, || {
        let mut acc = EstepAccum::zeros(c, f, r);
        for s in &utts {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
        acc.count
    });

    let mt = bench("estep/cpu-multithread", 1, 3, || {
        let chunk = utts.len().div_ceil(workers);
        let parts = map_parallel(utts.len().div_ceil(chunk), workers, |k| {
            let mut acc = EstepAccum::zeros(c, f, r);
            for s in &utts[k * chunk..((k + 1) * chunk).min(utts.len())] {
                estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
            }
            acc
        });
        parts.len()
    });

    let mut accel = AccelTvm::new("artifacts").unwrap();
    accel.set_model(&model).unwrap();
    let dev = bench("estep/accel", 1, 3, || {
        let mut acc = EstepAccum::zeros(c, f, r);
        for chunk in utts.chunks(accel.dims.bu) {
            let refs: Vec<&UttStats> = chunk.iter().collect();
            let (a, _) = accel.estep_batch(&refs).unwrap();
            acc.merge(&a);
        }
        acc.count
    });
    println!(
        "-> accel vs scalar {:.1}x, vs multithread {:.1}x",
        scalar.median_s / dev.median_s,
        mt.median_s / dev.median_s
    );
}
