//! Bench: one full extractor-training iteration, CPU vs accelerated
//! coordinator path (the paper's headline 25× training speed-up).

use ivector_tv::bench_util::bench;
use ivector_tv::config::Config;
use ivector_tv::coordinator::{train_tvm, ComputePath, TrainSetup};
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::{AccelTvm, Formulation, TrainVariant};

fn main() {
    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = 24;
    cfg.corpus.utts_per_train_speaker = 6;
    let corpus = generate_corpus(&cfg.corpus).unwrap();
    let (ubm, _) = train_ubm(&corpus.train, &cfg.ubm, 1).unwrap();
    let variant = TrainVariant {
        formulation: Formulation::Augmented,
        min_divergence: true,
        sigma_update: true,
        realign_every: None,
    };
    println!("training bench: {} utts, 2 EM iterations per rep", corpus.train.utts.len());

    let cpu = bench("train-2-iters/cpu", 0, 3, || {
        let mut setup = TrainSetup {
            cfg: &cfg,
            feats: &corpus.train,
            diag: ubm.diag.clone(),
            full: ubm.full.clone(),
        };
        train_tvm(&mut setup, variant, 2, 3, ComputePath::CpuRef, None, &mut |_| None).unwrap();
    });

    let mut accel = AccelTvm::new("artifacts").unwrap().with_alignment().unwrap();
    let dev = bench("train-2-iters/accel", 0, 3, || {
        let mut setup = TrainSetup {
            cfg: &cfg,
            feats: &corpus.train,
            diag: ubm.diag.clone(),
            full: ubm.full.clone(),
        };
        train_tvm(&mut setup, variant, 2, 3, ComputePath::Accel, Some(&mut accel), &mut |_| None)
            .unwrap();
    });
    println!("-> training speedup accel/cpu: {:.2}x (paper: 25x GPU vs 22-core)", cpu.median_s / dev.median_s);
}
