//! Figure 3 reproduction: the augmented formulation (+Σ updates) with
//! in-training frame-alignment updates at varying intervals — the
//! paper's §3.2 contribution.
//!
//!     cargo run --release --example fig3_realignment -- \
//!         [--seeds N] [--iters N] [--full]
//!
//! Paper finding: more frequent updates improve faster, and any update
//! schedule ends ~1% (relative) below never-updating.

use ivector_tv::config::Config;
use ivector_tv::coordinator::ensemble::{mean_curve, run_curve};
use ivector_tv::coordinator::ComputePath;
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::{AccelTvm, Formulation, TrainVariant};
use ivector_tv::metrics::Stopwatch;

fn arg(name: &str, default: usize) -> usize {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let seeds = arg("--seeds", if full { 5 } else { 2 });
    let iters = arg("--iters", if full { 20 } else { 12 });
    // paper sweeps every-1 … every-7; scaled default keeps the
    // endpoints plus the never-update baseline
    let intervals: Vec<Option<usize>> = if full {
        vec![Some(1), Some(2), Some(3), Some(5), Some(7), None]
    } else {
        vec![Some(1), Some(3), None]
    };

    let mut cfg = Config::default_scaled();
    if !full {
        // budget-scaled corpus (single-core testbed)
        cfg.corpus.n_train_speakers = 100;
        cfg.corpus.utts_per_train_speaker = 8;
        cfg.corpus.n_eval_speakers = 30;
        cfg.corpus.utts_per_eval_speaker = 6;
    }
    println!("== Fig. 3: realignment intervals ({seeds} seeds × {iters} iters) ==");
    let sw = Stopwatch::start();
    let corpus = generate_corpus(&cfg.corpus)?;
    let (ubm, _) = train_ubm(&corpus.train, &cfg.ubm, cfg.corpus.seed)?;
    println!("setup in {:.0}s", sw.elapsed_s());
    let mut accel = AccelTvm::new("artifacts")?.with_alignment()?;

    let mut results: Vec<(String, Vec<f64>)> = Vec::new();
    for interval in &intervals {
        let variant = TrainVariant {
            formulation: Formulation::Augmented,
            min_divergence: true,
            sigma_update: true,
            realign_every: *interval,
        };
        let label = match interval {
            Some(k) => format!("realign-every-{k}"),
            None => "no-realignment".to_string(),
        };
        let sw = Stopwatch::start();
        let mut curves = Vec::new();
        for seed in 0..seeds as u64 {
            let (_m, curve) = run_curve(
                &cfg,
                &corpus.train,
                &corpus.eval,
                &ubm.diag,
                &ubm.full,
                variant,
                iters,
                2000 + seed,
                1,
                ComputePath::Accel,
                Some(&mut accel),
            )?;
            curves.push(curve);
        }
        let mean = mean_curve(&curves);
        println!(
            "{label:<18} final EER {:.2}%  best {:.2}%  ({:.0}s)",
            mean.last().copied().unwrap_or(f64::NAN),
            mean.iter().cloned().fold(f64::INFINITY, f64::min),
            sw.elapsed_s()
        );
        results.push((label, mean));
    }

    println!("\n-- Fig. 3 series (EER %, mean of {seeds} seeds) --");
    print!("{:>6}", "iter");
    for (label, _) in &results {
        print!(" {:>18}", label);
    }
    println!();
    let n = results.iter().map(|(_, m)| m.len()).min().unwrap_or(0);
    for k in 0..n {
        print!("{:>6}", k + 1);
        for (_, m) in &results {
            print!(" {:>18.2}", m[k]);
        }
        println!();
    }

    let base = results.last().map(|(_, m)| *m.last().unwrap_or(&f64::NAN)).unwrap_or(f64::NAN);
    let best_realign = results
        .iter()
        .filter(|(l, _)| l != "no-realignment")
        .filter_map(|(_, m)| m.last())
        .cloned()
        .fold(f64::INFINITY, f64::min);
    println!(
        "\ncheck vs paper §4.3 (realignment beats no-realignment): {}",
        if best_realign < base {
            format!("REPRODUCED ({best_realign:.2}% < {base:.2}%)")
        } else {
            format!("NOT REPRODUCED ({best_realign:.2}% vs {base:.2}%)")
        }
    );
    Ok(())
}
