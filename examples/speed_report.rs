//! §4.2 speed table reproduction: real-time factors for frame
//! alignment and i-vector extraction, plus the extractor-training
//! speed-up of the accelerated path over the scalar CPU baseline
//! (the paper: 3000× RT alignment, 10 000× RT extraction, 25×
//! training speed-up of GPU over the 22-core Kaldi CPU baseline).
//!
//! Also runs a kernel-level microbench of the batched CPU paths
//! (scalar vs GEMM-shaped) at paper-class dims and records the
//! machine-readable trajectory in `BENCH_1.json` (frames/sec for
//! alignment, utterances/sec for the E-step), plus a serving-path load
//! replay (tiny in-process engine, micro-batched vs unbatched) whose
//! p50/p95/p99 latency and throughput land in `BENCH_2.json`, and a
//! cluster 1-vs-2 replica scaling replay (saturating load, rolling
//! swap mid-run) written to `BENCH_5.json` — so future PRs can track
//! every perf curve.
//!
//!     cargo run --release --example speed_report \
//!         [-- --utts N --bench-c C --bench-f F --bench-r R \
//!             --bench-frames T --bench-utts U \
//!             --serve-requests N --serve-concurrency C \
//!             --cluster-requests N]
//!
//! The accelerated sections are skipped (with a note) when
//! `artifacts/` is missing, so the CPU report runs everywhere.

use ivector_tv::bench_util::bench;
use ivector_tv::config::Config;
use ivector_tv::coordinator::{
    align_archive_accel, align_archive_cpu, align_archive_cpu_scalar, stats_from_posts,
    ComputePath, TrainSetup,
};
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::{train_ubm, BatchAligner, DiagGmm, FullGmm};
use ivector_tv::ivector::{
    estep_batch_cpu, estep_utterance, extract_cpu, AccelTvm, EstepAccum, EstepWorkspace,
    Formulation, TrainVariant, TvModel, UttStats,
};
use ivector_tv::linalg::Mat;
use ivector_tv::metrics::{markdown_table, StageReport, Stopwatch};
use ivector_tv::rng::Rng;

fn arg_usize(argv: &[String], flag: &str, default: usize) -> usize {
    argv.iter()
        .position(|a| a == flag)
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let n_utts = arg_usize(&argv, "--utts", 400);

    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = n_utts.div_ceil(8);
    cfg.corpus.utts_per_train_speaker = 8;
    println!("== §4.2 speed report ({n_utts} utts target) ==");

    let corpus = generate_corpus(&cfg.corpus)?;
    let train = &corpus.train;
    let frames = train.total_frames();
    println!("corpus: {} utts, {frames} frames (= {:.0}s of nominal audio)", train.utts.len(), frames as f64 * 0.01);
    let (ubm, _) = train_ubm(train, &cfg.ubm, 1)?;
    let mut accel = match AccelTvm::new("artifacts").and_then(AccelTvm::with_alignment) {
        Ok(a) => Some(a),
        Err(e) => {
            println!("note: accel sections skipped (artifacts unavailable): {e:#}");
            None
        }
    };
    let workers = ivector_tv::exec::default_workers();
    let mut rows = Vec::new();

    // ---- frame alignment (paper: 3000× RT on Titan V) ----
    let sw = Stopwatch::start();
    let _posts_scalar = align_archive_cpu_scalar(
        &ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers,
    );
    let scalar_s = sw.elapsed_s();
    rows.push(StageReport::new("align (cpu-scalar)", scalar_s, frames, "frames").with_rt(frames));

    let sw = Stopwatch::start();
    let posts_cpu =
        align_archive_cpu(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers);
    let cpu_s = sw.elapsed_s();
    rows.push(StageReport::new("align (cpu-batched)", cpu_s, frames, "frames").with_rt(frames));
    println!("-> align cpu batched/scalar speedup: {:.2}x", scalar_s / cpu_s);

    let mut align_speedup_accel = None;
    if let Some(accel) = &accel {
        let sw = Stopwatch::start();
        let _posts_dev = align_archive_accel(accel, &ubm.diag, &ubm.full, train)?;
        let dev_s = sw.elapsed_s();
        rows.push(StageReport::new("align (accel)", dev_s, frames, "frames").with_rt(frames));
        align_speedup_accel = Some(cpu_s / dev_s);
    }

    // ---- stats + model ----
    let (bw, _global) = stats_from_posts(train, &posts_cpu, cfg.ubm.components, workers);
    let model = TvModel::init(Formulation::Augmented, &ubm.full, cfg.tvm.rank, 100.0, 3);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &model)).collect();

    // ---- i-vector extraction (paper: 10 000× RT) ----
    let sw = Stopwatch::start();
    let _iv = extract_cpu(&model, &utts, workers);
    let cpu_s = sw.elapsed_s();
    rows.push(StageReport::new("extract (cpu-batched)", cpu_s, utts.len(), "utts").with_rt(frames));

    let mut extract_speedup = None;
    if let Some(accel) = &mut accel {
        accel.set_model(&model)?;
        let sw = Stopwatch::start();
        for chunk in utts.chunks(accel.dims.bu) {
            let refs: Vec<&UttStats> = chunk.iter().collect();
            let _ = accel.extract_batch(&refs, &model.prior_mean)?;
        }
        let dev_s = sw.elapsed_s();
        rows.push(StageReport::new("extract (accel)", dev_s, utts.len(), "utts").with_rt(frames));
        extract_speedup = Some(cpu_s / dev_s);
    }

    // ---- one full training E-step (the per-iteration hot loop;
    //      paper: 25× training speed-up over the CPU baseline) ----
    let sw = Stopwatch::start();
    {
        // per-item scalar baseline — the honest "Kaldi CPU" analogue
        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
        for s in &utts {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
    }
    let scalar_s = sw.elapsed_s();
    rows.push(StageReport::new("estep (cpu-scalar 1-thread)", scalar_s, utts.len(), "utts"));

    let sw = Stopwatch::start();
    {
        let consts = model.precompute_consts();
        let bu = cfg.tvm.batch_utts.max(1);
        let mut ws = EstepWorkspace::new(cfg.tvm.rank, bu);
        let mut acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
        for chunk in utts.chunks(bu) {
            let refs: Vec<&UttStats> = chunk.iter().collect();
            estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut acc));
        }
    }
    let batched_s = sw.elapsed_s();
    rows.push(StageReport::new("estep (cpu-batched 1-thread)", batched_s, utts.len(), "utts"));

    let mut estep_speedup_accel = None;
    if let Some(accel) = &accel {
        let sw = Stopwatch::start();
        let mut acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
        for chunk in utts.chunks(accel.dims.bu) {
            let refs: Vec<&UttStats> = chunk.iter().collect();
            let (a, _) = accel.estep_batch(&refs)?;
            acc.merge(&a);
        }
        let accel_s = sw.elapsed_s();
        rows.push(StageReport::new("estep (accel)", accel_s, utts.len(), "utts"));
        estep_speedup_accel = Some(scalar_s / accel_s);
    }

    // ---- one end-to-end training iteration both paths ----
    let variant = TrainVariant {
        formulation: Formulation::Augmented,
        min_divergence: true,
        sigma_update: true,
        realign_every: None,
    };
    let mut t_cpu = TrainSetup { cfg: &cfg, feats: train, diag: ubm.diag.clone(), full: ubm.full.clone() };
    let sw = Stopwatch::start();
    ivector_tv::coordinator::train_tvm(&mut t_cpu, variant, 1, 3, ComputePath::CpuRef, None, &mut |_| None)?;
    let iter_cpu = sw.elapsed_s();
    rows.push(StageReport::new("train-iter (cpu multi-thread)", iter_cpu, 1, "iter"));

    let mut iter_speedup = None;
    if let Some(accel) = &mut accel {
        let mut t_dev = TrainSetup { cfg: &cfg, feats: train, diag: ubm.diag.clone(), full: ubm.full.clone() };
        let sw = Stopwatch::start();
        ivector_tv::coordinator::train_tvm(&mut t_dev, variant, 1, 3, ComputePath::Accel, Some(accel), &mut |_| None)?;
        let iter_dev = sw.elapsed_s();
        rows.push(StageReport::new("train-iter (accel)", iter_dev, 1, "iter"));
        iter_speedup = Some(iter_cpu / iter_dev);
    }

    println!("\n{}", markdown_table(&rows));
    println!("| metric | paper (Titan V vs 22-core Xeon) | this testbed |");
    println!("|---|---|---|");
    if let Some(s) = align_speedup_accel {
        println!("| align speed-up accel/cpu-batched | — | {s:.1}× |");
    }
    if let Some(s) = extract_speedup {
        println!("| extract speed-up accel/cpu-batched | — | {s:.1}× |");
    }
    if let Some(s) = estep_speedup_accel {
        println!("| E-step speed-up accel/scalar | 25× (training) | {s:.1}× |");
    }
    if let Some(s) = iter_speedup {
        println!("| full-iteration speed-up | 25× | {s:.1}× |");
    }

    // ---- kernel microbench at paper-class dims → BENCH_1.json ----
    let bc = arg_usize(&argv, "--bench-c", 2048);
    let bf = arg_usize(&argv, "--bench-f", 60);
    // Paper-class rank by default (the acceptance dims). Footprint is
    // steep — the A accumulator alone is C·R²·8 bytes (~2.6 GB at
    // C=2048, R=400) and the scalar reference holds full TᵀΣ⁻¹T
    // matrices (another ~2.6 GB): ~7 GB peak. Pass --bench-r 200 on
    // smaller hosts.
    let br = arg_usize(&argv, "--bench-r", 400);
    let bframes = arg_usize(&argv, "--bench-frames", 1000);
    let butts = arg_usize(&argv, "--bench-utts", 8);
    kernel_bench_json(bc, bf, br, bframes, butts, cfg.tvm.top_k)?;

    // ---- serving-path load replay → BENCH_2.json ----
    let serve_requests = arg_usize(&argv, "--serve-requests", 1200);
    let serve_concurrency = arg_usize(&argv, "--serve-concurrency", 8);
    let tiny_cfg = ivector_tv::serve::bench::tiny_serve_config();
    let tiny_bundle = ivector_tv::serve::bench::train_tiny_bundle(&tiny_cfg, 42)?;
    serving_bench_json(&tiny_cfg, tiny_bundle, serve_requests, serve_concurrency)?;

    // ---- cluster 1-vs-2 replica scaling → BENCH_5.json ----
    let cluster_requests = arg_usize(&argv, "--cluster-requests", 900);
    cluster_bench_json(cluster_requests, serve_concurrency)?;
    Ok(())
}

/// Serving latency/throughput at tiny-engine dims: replay verify
/// traffic through the micro-batched engine and its unbatched twin,
/// write the `BENCH_2.json` serving section.
fn serving_bench_json(
    cfg: &Config,
    bundle: ivector_tv::serve::ModelBundle,
    requests: usize,
    concurrency: usize,
) -> anyhow::Result<()> {
    use ivector_tv::frontend::synth::TrafficGen;
    use ivector_tv::serve::bench::{run_batched_vs_unbatched, write_bench2_json, ServeBenchOpts};

    println!("\n== serving load replay ({requests} verify requests, {concurrency} clients) ==");
    let traffic = TrafficGen::new(&cfg.corpus, 8, 4242);
    let opts = ServeBenchOpts { speakers: 8, enroll_utts: 2, requests, concurrency };
    let (batched, unbatched, obs) =
        run_batched_vs_unbatched(bundle, &cfg.serve, &cfg.obs, &traffic, &opts)?;
    println!(
        "-> batched: {:.0} req/s (p50 {:.2} ms, p99 {:.2} ms, mean batch {:.2}); \
         unbatched: {:.0} req/s (p50 {:.2} ms, p99 {:.2} ms)",
        batched.throughput_rps,
        batched.verify.p50_s * 1e3,
        batched.verify.p99_s * 1e3,
        batched.mean_batch,
        unbatched.throughput_rps,
        unbatched.verify.p50_s * 1e3,
        unbatched.verify.p99_s * 1e3,
    );
    println!(
        "-> admission: shed {} / timeout {} of {} requests; queue depth max {} mean {:.1}",
        batched.shed_requests,
        batched.timed_out_requests,
        requests,
        batched.queue_depth_max,
        batched.queue_depth_mean,
    );
    for (stage, s) in obs.stage_summaries() {
        if s.count > 0 {
            println!(
                "-> stage {stage:<16} n {:>6}  p50 {:>8.3} ms  p99 {:>8.3} ms",
                s.count,
                s.p50_s * 1e3,
                s.p99_s * 1e3,
            );
        }
    }
    write_bench2_json("BENCH_2.json", &[("batched", &batched), ("unbatched", &unbatched)])?;
    println!("wrote BENCH_2.json");
    Ok(())
}

/// Cluster scaling: the same saturating verify load against a
/// 1-replica and a 2-replica dispatcher (rolling an identical-bundle
/// swap through the latter mid-run), written as the `BENCH_5.json`
/// section — the `cluster-bench` CLI run, in-process. Uses the
/// compute-heavy rank-64 bench bundle so the replica worker, not the
/// client pool, is the bottleneck the ratio measures.
fn cluster_bench_json(requests: usize, concurrency: usize) -> anyhow::Result<()> {
    use ivector_tv::frontend::synth::TrafficGen;
    use ivector_tv::serve::bench::train_tiny_bundle;
    use ivector_tv::serve::cluster::bench::{
        cluster_bench_config, run_cluster_load, saturation_serve_config, write_bench5_json,
        ClusterBenchOpts,
    };
    use ivector_tv::serve::Dispatcher;

    println!("\n== cluster scaling replay ({requests} verify requests, {concurrency} clients) ==");
    let cfg = cluster_bench_config();
    let bundle = train_tiny_bundle(&cfg, 42)?;
    let serve = saturation_serve_config(&cfg.serve);
    let traffic = TrafficGen::new(&cfg.corpus, 8, 5151);
    let opts = ClusterBenchOpts {
        speakers: 8,
        enroll_utts: 2,
        requests,
        concurrency,
        live_enroll_every: 16,
        stall_replica: None,
    };

    let mut single = cfg.cluster.clone();
    single.replicas = 1;
    let d1 = Dispatcher::new(bundle.clone(), &serve, &single)?;
    let r1 = run_cluster_load(&d1, &traffic, &opts, None)?;
    drop(d1);

    let mut duo = cfg.cluster.clone();
    duo.replicas = 2;
    let d2 = Dispatcher::new(bundle.clone(), &serve, &duo)?;
    let r2 = run_cluster_load(&d2, &traffic, &opts, Some(&bundle))?;

    println!(
        "-> 1 replica: {:.0} completed req/s (p99 {:.2} ms, rejected {}); \
         2 replicas: {:.0} req/s (p99 {:.2} ms, rejected {}, failovers {}, \
         swaps {}, lost enrollments {}) = {:.2}x",
        r1.throughput_rps,
        r1.verify.p99_s * 1e3,
        r1.rejected,
        r2.throughput_rps,
        r2.verify.p99_s * 1e3,
        r2.rejected,
        r2.failovers,
        r2.swaps,
        r2.lost_enrollments,
        if r1.throughput_rps > 0.0 { r2.throughput_rps / r1.throughput_rps } else { 0.0 },
    );
    write_bench5_json(
        "BENCH_5.json",
        &[("replicas_1".to_string(), &r1), ("replicas_2".to_string(), &r2)],
    )?;
    println!("wrote BENCH_5.json");
    Ok(())
}

/// Single-threaded scalar-vs-batched kernel comparison on a synthetic
/// UBM/model at the requested dims; writes `BENCH_1.json`.
fn kernel_bench_json(
    c: usize,
    f: usize,
    r: usize,
    n_frames: usize,
    n_utts: usize,
    top_k: usize,
) -> anyhow::Result<()> {
    println!("\n== kernel microbench (C={c} F={f} R={r}, {n_frames} frames, {n_utts} utts) ==");
    let mut rng = Rng::seed(4242);
    let diag = DiagGmm {
        weights: rng.dirichlet(2.0, c),
        means: Mat::from_fn(c, f, |_, _| 2.0 * rng.normal()),
        vars: Mat::from_fn(c, f, |_, _| rng.uniform_in(0.5, 2.0)),
    };
    let full = FullGmm::from_diag(&diag)?;
    let frames = Mat::from_fn(n_frames, f, |_, _| 2.0 * rng.normal());

    let align_scalar = bench("kernel/align-scalar", 1, 3, || {
        ivector_tv::gmm::select_posteriors_scalar(&diag, &full, &frames, top_k, 0.025)
    });
    let align_batched = bench("kernel/align-batched", 1, 3, || {
        BatchAligner::new(&diag, &full, top_k, 0.025).align_utterance(&frames)
    });

    // mixed-precision comparison (same UBM, same frames, same run) →
    // BENCH_4.json: alignment frames/s for the f64 and f32 paths
    let precision_bench = ivector_tv::bench_util::bench_align_precision(
        &diag, &full, &frames, top_k, 0.025, 1, 3,
    );
    println!(
        "-> alignment precision: {:.0} frames/s f32 vs {:.0} f64 ({:.2}x)",
        precision_bench.frames_per_s_f32(),
        precision_bench.frames_per_s_f64(),
        precision_bench.f32_speedup(),
    );
    ivector_tv::bench_util::write_bench4_json("BENCH_4.json", &precision_bench)?;
    println!("wrote BENCH_4.json");

    let model = TvModel::init(Formulation::Augmented, &full, r, 100.0, 7);
    let stats: Vec<UttStats> = (0..n_utts)
        .map(|_| UttStats {
            n: (0..c).map(|_| rng.uniform_in(0.5, 30.0)).collect(),
            f: Mat::from_fn(c, f, |_, _| 3.0 * rng.normal()),
        })
        .collect();

    let estep_scalar = {
        let (tt_si, tt_si_t) = model.precompute();
        bench("kernel/estep-scalar", 1, 2, || {
            let mut acc = EstepAccum::zeros(c, f, r);
            for s in &stats {
                estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
            }
            acc.count
        })
    };
    let estep_batched = {
        let consts = model.precompute_consts();
        bench("kernel/estep-batched", 1, 2, || {
            let mut acc = EstepAccum::zeros(c, f, r);
            let mut ws = EstepWorkspace::new(r, stats.len());
            let refs: Vec<&UttStats> = stats.iter().collect();
            estep_batch_cpu(&refs, &consts, &mut ws, Some(&mut acc));
            acc.count
        })
    };

    let fps_scalar = n_frames as f64 / align_scalar.median_s;
    let fps_batched = n_frames as f64 / align_batched.median_s;
    let ups_scalar = n_utts as f64 / estep_scalar.median_s;
    let ups_batched = n_utts as f64 / estep_batched.median_s;
    let align_speedup = align_scalar.median_s / align_batched.median_s;
    let estep_speedup = estep_scalar.median_s / estep_batched.median_s;
    println!(
        "-> alignment {fps_batched:.0} frames/s vs {fps_scalar:.0} scalar ({align_speedup:.2}x); \
         estep {ups_batched:.2} utts/s vs {ups_scalar:.2} scalar ({estep_speedup:.2}x)"
    );

    let dims = format!(
        "{{\"C\": {c}, \"F\": {f}, \"R\": {r}, \"frames\": {n_frames}, \
\"utts\": {n_utts}, \"top_k\": {top_k}}}"
    );
    let alignment = format!(
        "{{\"scalar_s\": {:.6}, \"batched_s\": {:.6}, \
\"frames_per_s_scalar\": {fps_scalar:.2}, \"frames_per_s_batched\": {fps_batched:.2}, \
\"speedup\": {align_speedup:.3}}}",
        align_scalar.median_s, align_batched.median_s,
    );
    let estep = format!(
        "{{\"scalar_s\": {:.6}, \"batched_s\": {:.6}, \
\"utts_per_s_scalar\": {ups_scalar:.4}, \"utts_per_s_batched\": {ups_batched:.4}, \
\"speedup\": {estep_speedup:.3}}}",
        estep_scalar.median_s, estep_batched.median_s,
    );
    ivector_tv::bench_util::write_bench_json(
        "BENCH_1.json",
        1,
        &[("dims", dims), ("alignment", alignment), ("estep", estep)],
    )?;
    println!("wrote BENCH_1.json");
    Ok(())
}
