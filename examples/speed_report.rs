//! §4.2 speed table reproduction: real-time factors for frame
//! alignment and i-vector extraction, plus the extractor-training
//! speed-up of the accelerated path over the scalar CPU baseline
//! (the paper: 3000× RT alignment, 10 000× RT extraction, 25×
//! training speed-up of GPU over the 22-core Kaldi CPU baseline).
//!
//!     cargo run --release --example speed_report [-- --utts N]

use ivector_tv::config::Config;
use ivector_tv::coordinator::{
    align_archive_accel, align_archive_cpu, stats_from_posts, ComputePath, TrainSetup,
};
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::{
    estep_utterance, extract_cpu, AccelTvm, EstepAccum, Formulation, TrainVariant, TvModel,
    UttStats,
};
use ivector_tv::metrics::{markdown_table, rt_factor, StageReport, Stopwatch};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().collect();
    let n_utts: usize = argv
        .iter()
        .position(|a| a == "--utts")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);

    let mut cfg = Config::default_scaled();
    cfg.corpus.n_train_speakers = n_utts.div_ceil(8);
    cfg.corpus.utts_per_train_speaker = 8;
    println!("== §4.2 speed report ({n_utts} utts target) ==");

    let corpus = generate_corpus(&cfg.corpus)?;
    let train = &corpus.train;
    let frames = train.total_frames();
    println!("corpus: {} utts, {frames} frames (= {:.0}s of nominal audio)", train.utts.len(), frames as f64 * 0.01);
    let (ubm, _) = train_ubm(train, &cfg.ubm, 1)?;
    let mut accel = AccelTvm::new("artifacts")?.with_alignment()?;
    let workers = ivector_tv::exec::default_workers();
    let mut rows = Vec::new();

    // ---- frame alignment (paper: 3000× RT on Titan V) ----
    let sw = Stopwatch::start();
    let posts_cpu =
        align_archive_cpu(&ubm.diag, &ubm.full, train, cfg.tvm.top_k, cfg.tvm.min_post, workers);
    let cpu_s = sw.elapsed_s();
    rows.push(StageReport::new("align (cpu-ref)", cpu_s, frames, "frames").with_rt(frames));

    let sw = Stopwatch::start();
    let _posts_dev = align_archive_accel(&accel, &ubm.diag, &ubm.full, train)?;
    let dev_s = sw.elapsed_s();
    rows.push(StageReport::new("align (accel)", dev_s, frames, "frames").with_rt(frames));
    let align_speedup = cpu_s / dev_s;

    // ---- stats + model ----
    let (bw, _global) = stats_from_posts(train, &posts_cpu, cfg.ubm.components, workers);
    let model = TvModel::init(Formulation::Augmented, &ubm.full, cfg.tvm.rank, 100.0, 3);
    let utts: Vec<UttStats> = bw.iter().map(|b| UttStats::from_bw(b, &model)).collect();

    // ---- i-vector extraction (paper: 10 000× RT) ----
    let sw = Stopwatch::start();
    let _iv = extract_cpu(&model, &utts, workers);
    let cpu_s = sw.elapsed_s();
    rows.push(StageReport::new("extract (cpu-ref)", cpu_s, utts.len(), "utts").with_rt(frames));

    accel.set_model(&model)?;
    let sw = Stopwatch::start();
    for chunk in utts.chunks(accel.dims.bu) {
        let refs: Vec<&UttStats> = chunk.iter().collect();
        let _ = accel.extract_batch(&refs, &model.prior_mean)?;
    }
    let dev_s = sw.elapsed_s();
    rows.push(StageReport::new("extract (accel)", dev_s, utts.len(), "utts").with_rt(frames));
    let extract_speedup = cpu_s / dev_s;

    // ---- one full training E-step (the per-iteration hot loop;
    //      paper: 25× training speed-up over the CPU baseline) ----
    let sw = Stopwatch::start();
    {
        // scalar single-thread baseline — the honest "Kaldi CPU" analogue
        let (tt_si, tt_si_t) = model.precompute();
        let mut acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
        for s in &utts {
            estep_utterance(s, &tt_si, &tt_si_t, &model.prior_mean, Some(&mut acc));
        }
    }
    let scalar_s = sw.elapsed_s();
    rows.push(StageReport::new("estep (cpu 1-thread)", scalar_s, utts.len(), "utts"));

    let sw = Stopwatch::start();
    {
        let mut acc = EstepAccum::zeros(cfg.ubm.components, cfg.feat_dim(), cfg.tvm.rank);
        for chunk in utts.chunks(accel.dims.bu) {
            let refs: Vec<&UttStats> = chunk.iter().collect();
            let (a, _) = accel.estep_batch(&refs)?;
            acc.merge(&a);
        }
    }
    let accel_s = sw.elapsed_s();
    rows.push(StageReport::new("estep (accel)", accel_s, utts.len(), "utts"));
    let estep_speedup = scalar_s / accel_s;

    // ---- one end-to-end training iteration both paths ----
    let variant = TrainVariant {
        formulation: Formulation::Augmented,
        min_divergence: true,
        sigma_update: true,
        realign_every: None,
    };
    let mut t_cpu = TrainSetup { cfg: &cfg, feats: train, diag: ubm.diag.clone(), full: ubm.full.clone() };
    let sw = Stopwatch::start();
    ivector_tv::coordinator::train_tvm(&mut t_cpu, variant, 1, 3, ComputePath::CpuRef, None, &mut |_| None)?;
    let iter_cpu = sw.elapsed_s();
    rows.push(StageReport::new("train-iter (cpu multi-thread)", iter_cpu, 1, "iter"));

    let mut t_dev = TrainSetup { cfg: &cfg, feats: train, diag: ubm.diag.clone(), full: ubm.full.clone() };
    let sw = Stopwatch::start();
    ivector_tv::coordinator::train_tvm(&mut t_dev, variant, 1, 3, ComputePath::Accel, Some(&mut accel), &mut |_| None)?;
    let iter_dev = sw.elapsed_s();
    rows.push(StageReport::new("train-iter (accel)", iter_dev, 1, "iter"));

    println!("\n{}", markdown_table(&rows));
    println!("| metric | paper (Titan V vs 22-core Xeon) | this testbed (XLA-CPU vs scalar rust) |");
    println!("|---|---|---|");
    println!(
        "| alignment ×RT (accel) | ~3000× | {:.0}× |",
        rt_factor(frames, rows[1].wall_s)
    );
    println!(
        "| extraction ×RT (accel) | ~10000× | {:.0}× |",
        rt_factor(frames, rows[3].wall_s)
    );
    println!("| align speed-up accel/cpu-ref | — | {align_speedup:.1}× |");
    println!("| extract speed-up accel/cpu-ref | — | {extract_speedup:.1}× |");
    println!("| E-step speed-up accel/scalar | 25× (training) | {estep_speedup:.1}× |");
    println!("| full-iteration speed-up | 25× | {:.1}× |", iter_cpu / iter_dev);
    Ok(())
}
