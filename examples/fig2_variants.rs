//! Figure 2 reproduction: EER vs training iteration for the six
//! extractor variants (standard ±min-div ±Σ-update; augmented
//! ±Σ-update), each averaged over random restarts.
//!
//!     cargo run --release --example fig2_variants -- \
//!         [--seeds N] [--iters N] [--eval-every N] [--full] [--long]
//!
//! Defaults are budget-scaled (3 seeds × 14 iters, eval every 2);
//! `--full` matches the paper protocol shape (5 seeds × 25 iters,
//! eval every iteration); `--long` adds the 200-iteration single-run
//! convergence check of §4.3.

use ivector_tv::config::Config;
use ivector_tv::coordinator::ensemble::{mean_curve, run_curve_shared, SharedAlignment};
use ivector_tv::coordinator::{align_archive_cpu, run_alignment, stats_from_posts, ComputePath, TrainSetup};
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::{AccelTvm, TrainVariant};
use ivector_tv::metrics::Stopwatch;

fn arg(name: &str, default: usize) -> usize {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == name)
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let long = std::env::args().any(|a| a == "--long");
    let seeds = arg("--seeds", if full { 5 } else { 3 });
    let iters = arg("--iters", if full { 25 } else { 14 });
    let eval_every = arg("--eval-every", if full { 1 } else { 2 });

    let mut cfg = Config::default_scaled();
    if !full {
        // budget-scaled corpus (single-core testbed)
        cfg.corpus.n_train_speakers = 100;
        cfg.corpus.utts_per_train_speaker = 8;
        cfg.corpus.n_eval_speakers = 30;
        cfg.corpus.utts_per_eval_speaker = 6;
    }
    println!("== Fig. 2: variant comparison ({seeds} seeds × {iters} iters, eval every {eval_every}) ==");

    let sw = Stopwatch::start();
    let corpus = generate_corpus(&cfg.corpus)?;
    let (ubm, _) = train_ubm(&corpus.train, &cfg.ubm, cfg.corpus.seed)?;
    println!("setup: corpus + UBM in {:.0}s", sw.elapsed_s());

    let mut accel = AccelTvm::new("artifacts")?.with_alignment()?;

    // fig2 never realigns, so one alignment round serves all runs
    let sw = Stopwatch::start();
    let shared = {
        let setup = TrainSetup {
            cfg: &cfg,
            feats: &corpus.train,
            diag: ubm.diag.clone(),
            full: ubm.full.clone(),
        };
        let train_stats = run_alignment(&setup, ComputePath::Accel, Some(&accel), 1)?;
        let stats_of = |arch: &ivector_tv::io::FeatArchive| {
            let posts = align_archive_cpu(&ubm.diag, &ubm.full, arch, cfg.tvm.top_k, cfg.tvm.min_post, 1);
            stats_from_posts(arch, &posts, cfg.ubm.components, 1).0
        };
        SharedAlignment {
            train_stats,
            harness_stats: (stats_of(&corpus.train), stats_of(&corpus.eval)),
        }
    };
    println!("shared alignment in {:.0}s", sw.elapsed_s());
    let mut results: Vec<(String, Vec<f64>)> = Vec::new();

    for (label, variant) in TrainVariant::fig2_set() {
        let sw = Stopwatch::start();
        let mut curves = Vec::new();
        for seed in 0..seeds as u64 {
            let (_m, curve) = run_curve_shared(
                &cfg,
                &corpus.train,
                &corpus.eval,
                &ubm.diag,
                &ubm.full,
                variant,
                iters,
                1000 + seed,
                eval_every,
                ComputePath::Accel,
                Some(&mut accel),
                Some(&shared),
            )?;
            curves.push(curve);
        }
        let mean = mean_curve(&curves);
        println!(
            "{label:<24} final EER {:.2}%  best {:.2}%  ({:.0}s)",
            mean.last().copied().unwrap_or(f64::NAN),
            mean.iter().cloned().fold(f64::INFINITY, f64::min),
            sw.elapsed_s()
        );
        results.push((label, mean));
    }

    // the figure: one row per evaluated iteration, one column per variant
    println!("\n-- Fig. 2 series (EER %, mean of {seeds} seeds; rows = evaluated iterations) --");
    print!("{:>6}", "iter");
    for (label, _) in &results {
        print!(" {:>22}", label);
    }
    println!();
    let n_points = results.iter().map(|(_, m)| m.len()).min().unwrap_or(0);
    for k in 0..n_points {
        print!("{:>6}", (k + 1) * eval_every);
        for (_, mean) in &results {
            print!(" {:>22.2}", mean[k]);
        }
        println!();
    }

    // paper's qualitative claims, asserted on our data
    let final_of = |id: &str| {
        results
            .iter()
            .find(|(l, _)| l == id)
            .and_then(|(_, m)| m.last())
            .copied()
            .unwrap_or(f64::NAN)
    };
    let std_plain = final_of("standard");
    let std_md = final_of("standard+mindiv");
    let aug_sig = final_of("augmented+sigma");
    println!("\nchecks vs paper §4.3:");
    println!(
        "  min-div helps (std {std_plain:.2}% -> {std_md:.2}%): {}",
        if std_md < std_plain { "REPRODUCED" } else { "NOT REPRODUCED (noise?)" }
    );
    println!(
        "  best variant is augmented+sigma ({aug_sig:.2}%): {}",
        if results.iter().all(|(l, m)| l == "augmented+sigma" || m.last() >= Some(&aug_sig)) {
            "REPRODUCED"
        } else {
            "PARTIAL (see table)"
        }
    );

    if long {
        println!("\n-- §4.3 long-run check: augmented+sigma, 1 seed, 200 iterations --");
        let variant = TrainVariant {
            formulation: ivector_tv::ivector::Formulation::Augmented,
            min_divergence: true,
            sigma_update: true,
            realign_every: None,
        };
        let (_m, curve) = run_curve_shared(
            &cfg,
            &corpus.train,
            &corpus.eval,
            &ubm.diag,
            &ubm.full,
            variant,
            200,
            7,
            10,
            ComputePath::Accel,
            Some(&mut accel),
            Some(&shared),
        )?;
        for (k, eer) in curve.eer_by_iter.iter().enumerate() {
            println!("  iter {:>3}: EER {eer:.2}%", (k + 1) * 10);
        }
    }
    Ok(())
}
