//! Quickstart: the full pipeline end-to-end on the scaled corpus —
//! synth → UBM → i-vector extractor training (accelerated, with the
//! paper's recommended recipe) → extraction → LDA/PLDA → EER.
//!
//!     cargo run --release --example quickstart [-- --fast]
//!
//! This is the end-to-end driver recorded in EXPERIMENTS.md §BEST.

use ivector_tv::config::Config;
use ivector_tv::coordinator::ensemble::run_curve;
use ivector_tv::coordinator::ComputePath;
use ivector_tv::frontend::synth::generate_corpus;
use ivector_tv::gmm::train_ubm;
use ivector_tv::ivector::{AccelTvm, TrainVariant};
use ivector_tv::metrics::Stopwatch;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut cfg = Config::default_scaled();
    let iters = if fast {
        cfg.corpus.n_train_speakers = 48;
        cfg.corpus.utts_per_train_speaker = 5;
        cfg.corpus.n_eval_speakers = 12;
        cfg.backend.lda_dim = 24;
        4
    } else {
        cfg.tvm.iters
    };

    println!("== ivector-tv quickstart ==");
    println!(
        "corpus: {} train spk × {} utts, {} eval spk × {} utts; C={}, F={}, R={}",
        cfg.corpus.n_train_speakers,
        cfg.corpus.utts_per_train_speaker,
        cfg.corpus.n_eval_speakers,
        cfg.corpus.utts_per_eval_speaker,
        cfg.ubm.components,
        cfg.feat_dim(),
        cfg.tvm.rank
    );

    let sw = Stopwatch::start();
    let corpus = generate_corpus(&cfg.corpus)?;
    println!(
        "[1/4] synth: {} train utts / {} frames in {:.1}s",
        corpus.train.utts.len(),
        corpus.train.total_frames(),
        sw.elapsed_s()
    );

    let sw = Stopwatch::start();
    let (ubm, _) = train_ubm(&corpus.train, &cfg.ubm, cfg.corpus.seed)?;
    println!("[2/4] UBM: C={} full-cov in {:.1}s", cfg.ubm.components, sw.elapsed_s());

    let mut accel = AccelTvm::new("artifacts")?.with_alignment()?;
    let variant = TrainVariant::recommended(2); // paper §5 recipe
    println!(
        "[3/4] training extractor: variant={} iters={iters} (accelerated path)",
        variant.id()
    );
    let sw = Stopwatch::start();
    let (model, curve) = run_curve(
        &cfg,
        &corpus.train,
        &corpus.eval,
        &ubm.diag,
        &ubm.full,
        variant,
        iters,
        42,
        1,
        ComputePath::Accel,
        Some(&mut accel),
    )?;
    println!("      trained in {:.1}s", sw.elapsed_s());
    println!("      EER by iteration (%):");
    for (i, (eer, st)) in curve.eer_by_iter.iter().zip(&curve.iter_stats).enumerate() {
        println!(
            "        iter {:>2}: EER {eer:5.2}%   estep {:.2}s  mstep {:.2}s  device-util {}",
            i,
            st.estep_s,
            st.mstep_s,
            st.device_util.map(|u| format!("{:.0}%", u * 100.0)).unwrap_or_else(|| "—".into()),
        );
    }

    let final_eer = curve.eer_by_iter.last().copied().unwrap_or(f64::NAN);
    println!(
        "[4/4] final: EER {final_eer:.2}% over pooled trials (paper at full scale: 4.6%)\n      model rank {} prior offset {:.2}",
        model.rank(),
        model.prior_mean[0]
    );
    Ok(())
}
